//! The static rule walk: gaming detection (A1xx/A2xx) and constraint-cliff
//! warnings (C4xx) over the paired AST + lowered IR.
//!
//! The AST carries source offsets (spans, fix-its); the IR carries resolved
//! facts (epilogue op values, tiles, stages, alignments). Lowering maps
//! epilogue calls 1:1 in order, so `spec.epilogue[i]` is the source form of
//! `cfg.epilogue[i]` — the walk zips them instead of re-parsing arguments.

use crate::dsl::ast::{Program, Stage, TransposeSpec};
use crate::dsl::ir::{Arch, ConfigIr, EpilogueOp, ProgramIr, StageIr};
use crate::dsl::plan::{epilogue_smem_bytes, stage_smem_bytes};
use crate::dsl::validate::constraint_table;
use crate::dsl::KernelSpec;

use super::{Diagnostic, Fix, RuleId, Span};

/// All purely-static rules over one program.
pub fn run_static_rules(
    src: &str,
    ast: &Program,
    ir: &ProgramIr,
    arch_override: Option<Arch>,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let kernels = paired_kernels(ast, ir);
    // Flattened (position, op) list across all kernel chains in program
    // order — aux_store/aux_load dataflow may cross pipeline stages.
    let all_ops: Vec<&EpilogueOp> =
        kernels.iter().flat_map(|(_, cfg)| cfg.epilogue.iter()).collect();
    let mut flat_pos = 0usize;
    for (spec, cfg) in &kernels {
        epilogue_rules(src, spec, cfg, flat_pos, &all_ops, &mut out);
        cliff_rules(src, spec, cfg, arch_override, &mut out);
        flat_pos += cfg.epilogue.len();
    }
    if let Program::Pipeline(stages) = ast {
        transpose_rules(src, stages, &mut out);
    }
    out
}

/// AST kernel specs zipped with their lowered configs (stage-aligned:
/// lowering preserves order and arity).
fn paired_kernels<'a>(
    ast: &'a Program,
    ir: &'a ProgramIr,
) -> Vec<(&'a KernelSpec, &'a ConfigIr)> {
    match (ast, ir) {
        (Program::Kernel(s), ProgramIr::Kernel(k)) => vec![(s, k)],
        (Program::Pipeline(stages), ProgramIr::Pipeline(p)) => {
            let specs = stages.iter().filter_map(|s| match s {
                Stage::Kernel(k) => Some(k),
                _ => None,
            });
            let cfgs = p.stages.iter().filter_map(|s| match s {
                StageIr::Kernel(k) => Some(k),
                _ => None,
            });
            specs.zip(cfgs).collect()
        }
        _ => vec![],
    }
}

// ---------------------------------------------------------------------------
// A1xx/A2xx: static gaming detection (dataflow walk over epilogue chains)
// ---------------------------------------------------------------------------

fn epilogue_rules(
    src: &str,
    spec: &KernelSpec,
    cfg: &ConfigIr,
    flat_base: usize,
    all_ops: &[&EpilogueOp],
    out: &mut Vec<Diagnostic>,
) {
    for (i, (call, op)) in spec.epilogue.iter().zip(&cfg.epilogue).enumerate() {
        let span = call_span(src, call.offset);
        let removal = epilogue_removal_fix(src, span, &format!("remove `{}`", call.name));
        match op {
            EpilogueOp::Scale { value } if *value == 0.0 => {
                out.push(
                    Diagnostic::new(
                        RuleId::AccumulatorDrop,
                        "scale(0) multiplies the accumulator by zero",
                        "every FLOP the main loop computes is discarded; the kernel \
                         degenerates to a constant write, so any measured speedup is \
                         benchmark gaming, not optimization (§4.4)",
                    )
                    .with_span(span)
                    .with_fix(removal),
                );
            }
            EpilogueOp::Scale { value } if *value == 1.0 => {
                out.push(
                    Diagnostic::new(
                        RuleId::IdentityChain,
                        "scale(1) is the identity",
                        "the op consumes an EVT fusion slot and trial variance \
                         without changing the output",
                    )
                    .with_span(span)
                    .with_fix(removal),
                );
            }
            EpilogueOp::LeakyRelu { alpha } if *alpha == 1.0 => {
                out.push(
                    Diagnostic::new(
                        RuleId::IdentityChain,
                        "leaky_relu(alpha=1) is the identity",
                        "with alpha = 1 the negative branch equals the positive one; \
                         the op consumes an EVT fusion slot without changing the output",
                    )
                    .with_span(span)
                    .with_fix(removal),
                );
            }
            EpilogueOp::Clip { lo, hi } if lo == hi => {
                out.push(
                    Diagnostic::new(
                        RuleId::SolImplausible,
                        format!("clip({lo}, {hi}) forces a constant output"),
                        "every element clamps to the same value regardless of the \
                         computed product; a measurement of this kernel can undercut \
                         the SOL bound only because the declared computation is no \
                         longer performed (constant-output gaming, §4.4)",
                    )
                    .with_span(span)
                    .with_fix(removal),
                );
            }
            EpilogueOp::AuxStore { name } => {
                let loaded_later = all_ops[flat_base + i + 1..].iter().any(
                    |o| matches!(o, EpilogueOp::AuxLoad { name: n } if n == name),
                );
                if !loaded_later {
                    out.push(
                        Diagnostic::new(
                            RuleId::DeadStage,
                            format!("aux_store('{name}') is never aux_load-ed"),
                            "the stored tensor is unobservable downstream: the store \
                             is dead weight in the epilogue, and a chain built around \
                             it can hide skipped computation",
                        )
                        .with_span(span)
                        .with_fix(epilogue_removal_fix(
                            src,
                            span,
                            &format!("remove the dead aux_store('{name}')"),
                        )),
                    );
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// A201: dead transform stages in pipelines
// ---------------------------------------------------------------------------

fn transpose_rules(src: &str, stages: &[Stage], out: &mut Vec<Diagnostic>) {
    let mut skip_next = false;
    for (i, st) in stages.iter().enumerate() {
        let Stage::Transpose(tr) = st else { continue };
        if skip_next {
            skip_next = false;
            continue;
        }
        // self-inverse: transpose(x, L, L) with no dtype change
        if tr.from_layout == tr.to_layout && tr.from_dtype == tr.to_dtype {
            let span = call_span(src, tr.offset);
            out.push(
                Diagnostic::new(
                    RuleId::DeadStage,
                    format!(
                        "transpose({}, {}, {}) is a no-op",
                        tr.target, tr.from_layout, tr.to_layout
                    ),
                    "source and destination layout (and dtype) are identical; the \
                     stage moves bytes without observable effect — the shape of a \
                     fake-transpose exploit (§6.3)",
                )
                .with_span(span)
                .with_fix(stage_removal_fix(src, span, "remove the no-op transpose")),
            );
            continue;
        }
        // adjacent cancelling pair on the same target
        if let Some(Stage::Transpose(next)) = stages.get(i + 1) {
            if cancels(tr, next) {
                let a = call_span(src, tr.offset);
                let b = call_span(src, next.offset);
                let span = Span::new(a.offset, b.end().saturating_sub(a.offset));
                out.push(
                    Diagnostic::new(
                        RuleId::DeadStage,
                        format!(
                            "transpose pair on `{}` cancels: {}->{} then {}->{}",
                            tr.target,
                            tr.from_layout,
                            tr.to_layout,
                            next.from_layout,
                            next.to_layout
                        ),
                        "the second transform exactly inverts the first; both stages \
                         are dead weight that inflates apparent work",
                    )
                    .with_span(span)
                    .with_fix(stage_removal_fix(src, span, "remove the cancelling pair")),
                );
                skip_next = true;
            }
        }
    }
}

fn cancels(a: &TransposeSpec, b: &TransposeSpec) -> bool {
    a.target == b.target
        && b.from_layout == a.to_layout
        && b.to_layout == a.from_layout
        && b.from_dtype == a.to_dtype
        && b.to_dtype == a.from_dtype
}

// ---------------------------------------------------------------------------
// C4xx: constraint-cliff warnings — valid, but one step from a reject
// ---------------------------------------------------------------------------

fn cliff_rules(
    src: &str,
    spec: &KernelSpec,
    cfg: &ConfigIr,
    arch_override: Option<Arch>,
    out: &mut Vec<Diagnostic>,
) {
    let Some(arch) = arch_override.or(cfg.arch) else { return };
    let (Some(din), dout_opt) = (cfg.dtype_input, cfg.dtype_output) else { return };
    let dout = dout_opt.unwrap_or(din);
    let t = constraint_table(arch);

    // C401: SMEM within one pipeline stage of the budget
    if t.enforce_smem_budget {
        if let (Some(stages), Some(tl)) = (cfg.stages, cfg.tile) {
            let per_stage = stage_smem_bytes(tl, din);
            let epi = epilogue_smem_bytes(cfg.scheduler.unwrap_or_default().epilogue, tl, dout);
            let budget = t.smem_bytes - t.smem_reserved;
            let need = stages * per_stage + epi;
            if per_stage > 0 && need <= budget && need + per_stage > budget {
                let mut d = Diagnostic::new(
                    RuleId::SmemCliff,
                    format!(
                        "SMEM use {need} B is within one stage ({per_stage} B) of the \
                         {budget} B budget"
                    ),
                    "one more stage — or any tile/dtype growth — crosses the SM90 \
                     SMEM budget and turns this config into a hard reject (E004); \
                     nearby mutations of this candidate will be wasted trials",
                );
                if let Some(call) = spec.config("with_stages") {
                    let span = call_span(src, call.offset);
                    d = d.with_span(span);
                    if stages > 1 {
                        d = d.with_fix(Fix {
                            span,
                            replacement: format!("with_stages({})", stages - 1),
                            title: "step back from the SMEM cliff".into(),
                        });
                    }
                }
                out.push(d);
            }
        }
    }

    // C402: stage count exactly at the architecture maximum
    if let Some(stages) = cfg.stages {
        if stages == t.max_stages {
            let mut d = Diagnostic::new(
                RuleId::StagesAtMax,
                format!("with_stages({stages}) is the {arch} maximum"),
                format!(
                    "any upward mutation rejects (stages are between 1 and {}); \
                     deeper pipelining is not available on this architecture",
                    t.max_stages
                ),
            );
            if let Some(call) = spec.config("with_stages") {
                let span = call_span(src, call.offset);
                d = d.with_span(span).with_fix(Fix {
                    span,
                    replacement: format!("with_stages({})", t.max_stages - 1),
                    title: "step inside the stage limit".into(),
                });
            }
            out.push(d);
        }
    }

    // C403: alignment exactly at the TMA vector minimum
    if let Some(al) = cfg.alignment {
        if t.tma_vector_bytes > 0 {
            let ops = [("A", al.a, din), ("B", al.b, din), ("C", al.c, dout)];
            let at_min: Vec<&str> = ops
                .iter()
                .filter(|(_, v, d)| v * d.size() == t.tma_vector_bytes)
                .map(|(n, _, _)| *n)
                .collect();
            if !at_min.is_empty() {
                let mut d = Diagnostic::new(
                    RuleId::AlignmentAtTmaMin,
                    format!(
                        "operand alignment at the TMA minimum ({} bytes) for {}",
                        t.tma_vector_bytes,
                        at_min.join(", ")
                    ),
                    "halving any of these alignments violates the 16-byte TMA \
                     vector rule (E004); alignment-reducing mutations of this \
                     candidate are dead ends",
                );
                if let Some(call) = spec.config("with_alignment") {
                    let span = call_span(src, call.offset);
                    d = d.with_span(span);
                    let doubled = [al.a * 2, al.b * 2, al.c * 2];
                    if doubled.iter().all(|v| *v <= t.max_alignment_elems) {
                        d = d.with_fix(Fix {
                            span,
                            replacement: format!(
                                "with_alignment(A={}, B={}, C={})",
                                doubled[0], doubled[1], doubled[2]
                            ),
                            title: "double the alignments away from the TMA minimum".into(),
                        });
                    }
                }
                out.push(d);
            }
        }
    }

    // C404: tile dimension exactly at the architecture maximum
    if let Some(tl) = cfg.tile {
        let (mm, mn, mk) = t.max_tile;
        let at_max: Vec<&str> = [("m", tl.m, mm), ("n", tl.n, mn), ("k", tl.k, mk)]
            .iter()
            .filter(|(_, v, max)| v == max)
            .map(|(n, _, _)| *n)
            .collect();
        if !at_max.is_empty() {
            let spelling = if spec.config("with_threadblockshape").is_some() {
                "with_threadblockshape"
            } else {
                "with_tile"
            };
            let mut d = Diagnostic::new(
                RuleId::TileAtMax,
                format!(
                    "tile {}x{}x{} is at the {arch} maximum in {}",
                    tl.m,
                    tl.n,
                    tl.k,
                    at_max.join(", ")
                ),
                format!(
                    "any growth along {} rejects as implausibly large (E004); \
                     tile-growing mutations of this candidate are dead ends",
                    at_max.join("/")
                ),
            );
            if let Some(call) = spec.config(spelling) {
                let span = call_span(src, call.offset);
                let halved = (
                    if tl.m == mm { tl.m / 2 } else { tl.m },
                    if tl.n == mn { tl.n / 2 } else { tl.n },
                    if tl.k == mk { tl.k / 2 } else { tl.k },
                );
                d = d.with_span(span).with_fix(Fix {
                    span,
                    replacement: format!(
                        "{spelling}(m={}, n={}, k={})",
                        halved.0, halved.1, halved.2
                    ),
                    title: "halve the at-max tile dimension(s)".into(),
                });
            }
            out.push(d);
        }
    }
}

// ---------------------------------------------------------------------------
// Span helpers
// ---------------------------------------------------------------------------

/// Span of a call starting at the name ident at `offset`, through its
/// matching close paren. Quoted strings are skipped so `custom('f(x)')`
/// matches correctly. Falls back to a zero-length span when the source has
/// no paren at the site (cannot happen for parser-produced offsets).
fn call_span(src: &str, offset: usize) -> Span {
    let bytes = src.as_bytes();
    let mut i = offset;
    while i < bytes.len() && bytes[i] != b'(' {
        i += 1;
    }
    if i == bytes.len() {
        return Span::new(offset, 0);
    }
    let mut depth = 0usize;
    let mut in_str = false;
    while i < bytes.len() {
        match bytes[i] {
            b'\'' => in_str = !in_str,
            b'(' if !in_str => depth += 1,
            b')' if !in_str => {
                depth -= 1;
                if depth == 0 {
                    return Span::new(offset, i + 1 - offset);
                }
            }
            _ => {}
        }
        i += 1;
    }
    Span::new(offset, src.len() - offset)
}

/// Removal fix for an epilogue call: extend the span backwards over the
/// `>>` chain operator (and surrounding whitespace) so applying the fix
/// leaves a well-formed chain.
fn epilogue_removal_fix(src: &str, call: Span, title: &str) -> Fix {
    let bytes = src.as_bytes();
    let mut start = call.offset;
    while start > 0 && bytes[start - 1].is_ascii_whitespace() {
        start -= 1;
    }
    if start >= 2 && &src[start - 2..start] == ">>" {
        start -= 2;
        while start > 0 && bytes[start - 1].is_ascii_whitespace() {
            start -= 1;
        }
    }
    Fix {
        span: Span::new(start, call.end() - start),
        replacement: String::new(),
        title: title.to_string(),
    }
}

/// Removal fix for a pipeline stage: extend over the following comma if
/// present, else the preceding one, so the remaining stage list stays
/// comma-separated.
fn stage_removal_fix(src: &str, stage: Span, title: &str) -> Fix {
    let bytes = src.as_bytes();
    let mut end = stage.end();
    let mut fwd = end;
    while fwd < bytes.len() && bytes[fwd].is_ascii_whitespace() {
        fwd += 1;
    }
    let mut start = stage.offset;
    if fwd < bytes.len() && bytes[fwd] == b',' {
        end = fwd + 1;
        while end < bytes.len() && bytes[end] == b' ' {
            end += 1;
        }
    } else {
        let mut back = start;
        while back > 0 && bytes[back - 1].is_ascii_whitespace() {
            back -= 1;
        }
        if back > 0 && bytes[back - 1] == b',' {
            start = back - 1;
        }
    }
    Fix {
        span: Span::new(start, end - start),
        replacement: String::new(),
        title: title.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn call_span_matches_parens_and_skips_strings() {
        let src = "gemm() >> custom('f(x))', inputs={'y': 'z'}) >> relu()";
        let s = call_span(src, 10); // at `custom`
        assert_eq!(s.slice(src), "custom('f(x))', inputs={'y': 'z'})");
        let r = call_span(src, 48); // at `relu`
        assert_eq!(r.slice(src), "relu()");
    }

    #[test]
    fn epilogue_removal_extends_over_chain_operator() {
        let src = "gemm() >> bias() >> scale(1.0)";
        let call = call_span(src, 20);
        assert_eq!(call.slice(src), "scale(1.0)");
        let fix = epilogue_removal_fix(src, call, "remove");
        assert_eq!(fix.apply(src), "gemm() >> bias()");
    }

    #[test]
    fn stage_removal_keeps_commas_balanced() {
        let src = "pipeline(transpose(input, NCL, NCL), gemm())";
        let stage = call_span(src, 9);
        let fix = stage_removal_fix(src, stage, "remove");
        assert_eq!(fix.apply(src), "pipeline(gemm())");
        // last-stage form: eat the preceding comma instead
        let src2 = "pipeline(gemm(), transpose(output, NLC, NLC))";
        let stage2 = call_span(src2, 17);
        let fix2 = stage_removal_fix(src2, stage2, "remove");
        assert_eq!(fix2.apply(src2), "pipeline(gemm())");
    }
}
