//! SOL-infeasibility pruning (rule A101) and canonical-equivalence
//! deduplication (rule A301): the two analyzer verdicts cheap enough to sit
//! in the agent hot loop and skip evaluator calls entirely.
//!
//! # Soundness of the margin
//!
//! The analytic cost model is a *lower bound* on achievable time (ADR-006):
//! the simulated measurement for a candidate is `est × noise` with
//! `noise ~ lognormal(σ = 0.01)`. Pruning a candidate whose estimate
//! satisfies `est × MARGIN ≥ best` forfeits an improvement only if the
//! measured time lands below `best ≤ est × 0.94`, i.e. `noise < 0.94`,
//! which is `ln(0.94)/0.01 ≈ -6.2` standard deviations out — probability
//! ≈ 3e-10. At the paper's scale (thousands of trials) the expected number
//! of forfeited improvements is ~1e-6: the accepted-speedup geomeans are
//! bitwise unchanged (pinned by the twin-run property test in
//! `tests/lint.rs`), while every pruned candidate is one evaluator call
//! saved.
//!
//! # Interaction with the online scheduler (why the gate alone is not
//! sufficient)
//!
//! A pruned attempt feeds `None` into `StopRule::observe`, which counts as
//! a stale attempt. The unpruned twin feeds the measured time, which also
//! counts as stale *provided* the rule's internal best equals the session
//! best. Those can differ only when a sub-SOL (gaming) measurement set the
//! session best but was filtered out of the rule by the `0.9 × t_SOL`
//! implausibility check. The agent therefore additionally gates pruning on
//! `best ≥ 0.9 × t_SOL_fp16` and on a concrete best config being present —
//! see `controller::run_attempt`. Under those gates the pruned and
//! unpruned runs make identical stop decisions and identical future move
//! selections, which is what makes ADR-004 replay agree bit-for-bit.

use std::collections::HashSet;

use crate::scheduler::{Policy, StopRule};

use super::RuleId;

/// Estimate multiplier a candidate must still clear to be worth measuring.
/// `est × PRUNE_MARGIN ≥ best` ⇒ prune (see module docs for the 6σ
/// argument tying 0.94 to the σ = 0.01 lognormal measurement noise).
pub const PRUNE_MARGIN: f64 = 0.94;

/// Per-problem pruning state carried by an agent session: the margin and
/// the set of canonical config hashes already compiled this session.
#[derive(Debug, Clone)]
pub struct PruneGate {
    margin: f64,
    seen: HashSet<String>,
}

impl Default for PruneGate {
    fn default() -> Self {
        PruneGate::new()
    }
}

impl PruneGate {
    pub fn new() -> PruneGate {
        PruneGate { margin: PRUNE_MARGIN, seen: HashSet::new() }
    }

    /// Has this canonical config hash been compiled before this session?
    pub fn seen(&self, hash: &str) -> bool {
        self.seen.contains(hash)
    }

    /// Record a compiled candidate's canonical hash (call for *every*
    /// compiled DSL attempt, pruned or measured, so duplicate detection
    /// matches ADR-001's canonical-hash semantics).
    pub fn record(&mut self, hash: &str) {
        self.seen.insert(hash.to_string());
    }

    /// Pre-trial verdict for a candidate with analytic estimate `est_ms`
    /// against the session best `best_ms`. `None` = measure it.
    ///
    /// Duplicates are only reported when they are *also* SOL-infeasible:
    /// re-measuring a seen config draws fresh noise, so a near-best
    /// duplicate can still improve the session best and must be measured
    /// to keep twin runs identical.
    pub fn check(&self, est_ms: f64, best_ms: f64, hash: &str) -> Option<RuleId> {
        if !est_ms.is_finite() || !best_ms.is_finite() {
            return None;
        }
        if est_ms * self.margin >= best_ms {
            Some(if self.seen(hash) { RuleId::DuplicateConfig } else { RuleId::SolInfeasible })
        } else {
            None
        }
    }

    /// Band-aware refinement of [`check`](Self::check) for offline
    /// analysis: when the current best already sits inside the policy's
    /// SOL band (the scheduler is about to stop the problem anyway), an
    /// infeasible candidate is reported as A102 rather than A101 — same
    /// prune decision, more precise *why*. The agent hot loop does not
    /// pass a policy (re-labeling there would add no pruning and the
    /// session's stop decision already comes from `StopRule`), so A102
    /// surfaces through this library entry point and `repro lint` only.
    pub fn check_with_band(
        &self,
        est_ms: f64,
        best_ms: f64,
        hash: &str,
        policy: &Policy,
        t_sol_fp16_ms: f64,
    ) -> Option<RuleId> {
        let base = self.check(est_ms, best_ms, hash)?;
        if base == RuleId::SolInfeasible && StopRule::sol_band(policy, best_ms, t_sol_fp16_ms) {
            return Some(RuleId::SolBandStop);
        }
        Some(base)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn margin_gates_pruning() {
        let g = PruneGate::new();
        // est clearly above best: prune
        assert_eq!(g.check(2.0, 1.0, "h"), Some(RuleId::SolInfeasible));
        // est just under best/margin: must measure
        assert_eq!(g.check(1.05, 1.0, "h"), None);
        // boundary: est * 0.94 == best → prune (>= is the contract)
        assert_eq!(g.check(1.0 / PRUNE_MARGIN, 1.0, "h"), Some(RuleId::SolInfeasible));
        // no best yet (infinite): never prune
        assert_eq!(g.check(2.0, f64::INFINITY, "h"), None);
    }

    #[test]
    fn duplicates_reported_only_when_also_infeasible() {
        let mut g = PruneGate::new();
        g.record("dup");
        assert_eq!(g.check(2.0, 1.0, "dup"), Some(RuleId::DuplicateConfig));
        // seen but potentially-improving: measure anyway
        assert_eq!(g.check(1.0, 1.0, "dup"), None);
        assert!(g.seen("dup") && !g.seen("new"));
    }

    #[test]
    fn band_refines_label_not_decision() {
        let g = PruneGate::new();
        let tight = Policy { epsilon: 0.25, window: 0 };
        // best inside the (1+ε) band over SOL → A102
        assert_eq!(g.check_with_band(2.0, 1.1, "h", &tight, 1.0), Some(RuleId::SolBandStop));
        // best outside the band → plain A101
        assert_eq!(g.check_with_band(4.0, 2.0, "h", &tight, 1.0), Some(RuleId::SolInfeasible));
        // ε = off never bands
        let off = Policy::fixed();
        assert_eq!(g.check_with_band(2.0, 1.1, "h", &off, 1.0), Some(RuleId::SolInfeasible));
        // decision (Some/None) identical with and without the policy
        assert_eq!(g.check_with_band(1.0, 1.0, "h", &tight, 1.0), None);
    }
}
