//! Deterministic parallel execution engine (ADR-002).
//!
//! Fans independent (variant, problem, seed) evaluation tasks across the
//! std-only work-stealing [`pool`] while producing output **bit-identical
//! to the serial path**. Determinism comes from three rules:
//!
//! 1. every task derives a private RNG stream from its identity
//!    (`Pcg32::derive(seed, &[root, variant_id, pidx])`) — no task ever
//!    reads another task's draws;
//! 2. results are collected by task index, never by completion order;
//! 3. work with a genuine sequential dependency — the orchestrated
//!    controller's cross-problem memory chain — is *not* split: it runs as
//!    one task (parallelism then comes from other variants in the eval).
//!
//! `figures.rs`, `examples/full_eval.rs`, and the `repro` CLI all route
//! their suite evaluations through here; `--jobs N` selects the worker
//! count (`0` = all cores, `1` = the serial reference path). The
//! cross-process shard/merge protocol (`eval::manifest`, `repro shard` /
//! `repro merge`) partitions the same canonical [`suite_tasks`]
//! enumeration, so a sharded run merges back bit-identical to both the
//! serial and the in-process parallel paths.
//!
//! The engine is oracle-agnostic: every task evaluates through the `Env`
//! its bench hands out, so a record/replay backend installed with
//! `Bench::set_oracle` (ADR-004) is carried across the worker threads
//! unchanged — a trace recorded at any job count replays at any other,
//! because measurement identities never depend on task interleaving.

pub mod pool;

pub use pool::{effective_jobs, parallel_map};

use crate::agent::controller::{run_problem, ControllerKind, Env, VariantSpec};
use crate::agent::{ProblemRun, RunLog};
use crate::experiments::runner::{run_variant, Bench};
use crate::mantis::{run_orchestrated, CrossMemory, MantisConfig};

/// Can this variant's per-problem tasks run independently? Only the
/// orchestrated controller with cross-problem memory enabled has a
/// sequential dependency between problems.
fn problems_independent(spec: &VariantSpec, cfg: Option<&MantisConfig>) -> bool {
    spec.controller != ControllerKind::OrchestratedSol
        || !cfg.map(|c| c.cross_memory).unwrap_or(true)
}

/// One independent (variant, problem) task — must match what the serial
/// `run_variant` does per problem so the engine is bit-identical to it.
fn run_one(
    env: &Env,
    spec: &VariantSpec,
    cfg: Option<&MantisConfig>,
    pidx: usize,
    seed: u64,
) -> ProblemRun {
    match spec.controller {
        ControllerKind::OrchestratedSol => {
            let c = cfg.copied().unwrap_or_default();
            let mut fresh = CrossMemory::default();
            run_orchestrated(env, spec, pidx, seed, Some((&c, &mut fresh)))
        }
        _ => run_problem(env, spec, pidx, seed),
    }
}

/// Assemble a [`RunLog`] from a spec and its per-problem runs — the one
/// construction every execution path (serial, parallel, sharded merge)
/// shares, so their outputs are comparable field-for-field.
pub fn assemble_log(spec: &VariantSpec, runs: Vec<ProblemRun>) -> RunLog {
    RunLog {
        variant: spec.label(),
        tier_name: spec.tier.name().to_string(),
        price_per_mtok: spec.tier.params().price_per_mtok,
        runs,
    }
}

/// One unit of a suite evaluation: an independent (variant, problem)
/// session, or a whole sequentially-coupled variant (`problem == None`,
/// the orchestrated + cross-memory case of ADR-002). The deterministic
/// enumeration ([`suite_tasks`]) is shared by the parallel engine and the
/// shard/merge protocol (`eval::manifest`), so "what shard i of n runs" is
/// derived from the job description alone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SuiteTask {
    pub variant: usize,
    pub problem: Option<usize>,
}

impl SuiteTask {
    /// Stable task key for shard results ("v0003:p0042" / "v0003:whole").
    pub fn key(&self) -> String {
        match self.problem {
            Some(p) => format!("v{:04}:p{:04}", self.variant, p),
            None => format!("v{:04}:whole", self.variant),
        }
    }
}

/// Enumerate a suite evaluation's tasks in the canonical order: variants
/// in `work` order, independent variants fanned per problem in problem
/// order, coupled variants as one whole task.
pub fn suite_tasks(
    work: &[(VariantSpec, Option<MantisConfig>)],
    n_problems: usize,
) -> Vec<SuiteTask> {
    let mut tasks = Vec::new();
    for (v, (spec, cfg)) in work.iter().enumerate() {
        if problems_independent(spec, cfg.as_ref()) {
            for p in 0..n_problems {
                tasks.push(SuiteTask { variant: v, problem: Some(p) });
            }
        } else {
            tasks.push(SuiteTask { variant: v, problem: None });
        }
    }
    tasks
}

/// Execute one suite task: one run for an independent task, the whole
/// suite (in problem order) for a whole-variant task. Matches what the
/// serial `run_variant` produces for the same positions bit-for-bit.
pub fn run_suite_task(
    bench: &Bench,
    work: &[(VariantSpec, Option<MantisConfig>)],
    task: SuiteTask,
    seed: u64,
) -> Vec<ProblemRun> {
    let (spec, cfg) = &work[task.variant];
    match task.problem {
        Some(p) => vec![run_one(&bench.env(), spec, cfg.as_ref(), p, seed)],
        None => run_variant(bench, spec, seed, cfg.as_ref()).runs,
    }
}

/// Parallel [`run_variant`]: identical output, `jobs` workers. Variants
/// whose problems are sequentially coupled (orchestrated + cross-memory)
/// fall back to the serial path — splitting them would change results.
pub fn run_variant_jobs(
    bench: &Bench,
    spec: &VariantSpec,
    seed: u64,
    mantis_cfg: Option<&MantisConfig>,
    jobs: usize,
) -> RunLog {
    if jobs == 1 || !problems_independent(spec, mantis_cfg) {
        return run_variant(bench, spec, seed, mantis_cfg);
    }
    let env = bench.env();
    let runs = parallel_map(jobs, bench.problems.len(), |pidx| {
        run_one(&env, spec, mantis_cfg, pidx, seed)
    });
    assemble_log(spec, runs)
}

/// Evaluate several variants over the whole suite, fanning every
/// independent (variant, problem) pair across the pool. Sequentially
/// coupled variants contribute one whole-variant task each, so a
/// multi-variant eval still parallelizes around them. Output is
/// bit-identical to mapping [`run_variant`] over `work` serially.
pub fn eval_variants(
    bench: &Bench,
    work: &[(VariantSpec, Option<MantisConfig>)],
    seed: u64,
    jobs: usize,
) -> Vec<RunLog> {
    if jobs == 1 {
        return work
            .iter()
            .map(|(spec, cfg)| run_variant(bench, spec, seed, cfg.as_ref()))
            .collect();
    }

    // The same canonical task enumeration the shard/merge protocol uses
    // (eval::manifest): shard i of n runs ranks i, i+n, i+2n, … of exactly
    // this list.
    let tasks = suite_tasks(work, bench.problems.len());

    enum Done {
        One(usize, ProblemRun),
        Whole(usize, RunLog),
    }
    let env = bench.env();
    let results = parallel_map(jobs, tasks.len(), |i| match tasks[i] {
        SuiteTask { variant: v, problem: Some(p) } => {
            let (spec, cfg) = &work[v];
            Done::One(v, run_one(&env, spec, cfg.as_ref(), p, seed))
        }
        SuiteTask { variant: v, problem: None } => {
            let (spec, cfg) = &work[v];
            Done::Whole(v, run_variant(bench, spec, seed, cfg.as_ref()))
        }
    });

    // Reassemble in variant order; per-variant tasks were emitted in
    // problem order and parallel_map preserves task order.
    let mut per_variant: Vec<Vec<ProblemRun>> = (0..work.len()).map(|_| Vec::new()).collect();
    let mut whole: Vec<Option<RunLog>> = (0..work.len()).map(|_| None).collect();
    for r in results {
        match r {
            Done::One(v, run) => per_variant[v].push(run),
            Done::Whole(v, log) => whole[v] = Some(log),
        }
    }
    work.iter()
        .enumerate()
        .map(|(v, (spec, _))| match whole[v].take() {
            Some(log) => log,
            None => assemble_log(spec, std::mem::take(&mut per_variant[v])),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::ModelTier;
    use crate::experiments::runner::main_variants;

    #[test]
    fn parallel_engine_determinism_flat_variant() {
        let bench = Bench::new();
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);
        let serial = run_variant(&bench, &spec, 7, None);
        let par = run_variant_jobs(&bench, &spec, 7, None, 4);
        assert_eq!(par, serial, "jobs=4 must be bit-identical to the serial path");
        // and the JSON artifact (what experiments persist) is byte-equal
        assert_eq!(par.to_json().to_string(), serial.to_json().to_string());
    }

    #[test]
    fn parallel_engine_determinism_orchestrated_fallback() {
        // default MANTIS config has cross-problem memory on: the engine
        // must keep the sequential chain and still match exactly
        let bench = Bench::new();
        let spec = VariantSpec::new(ControllerKind::OrchestratedSol, false, ModelTier::Mini);
        let serial = run_variant(&bench, &spec, 3, None);
        let par = run_variant_jobs(&bench, &spec, 3, None, 4);
        assert_eq!(par, serial);
    }

    #[test]
    fn parallel_engine_determinism_orchestrated_no_xmem() {
        let bench = Bench::new();
        let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini);
        let cfg = MantisConfig::ablation("MANTIS-noXmem");
        let serial = run_variant(&bench, &spec, 11, Some(&cfg));
        let par = run_variant_jobs(&bench, &spec, 11, Some(&cfg), 3);
        assert_eq!(par, serial);
    }

    #[test]
    fn eval_variants_determinism_mixed_work() {
        let bench = Bench::new();
        let work: Vec<(VariantSpec, Option<MantisConfig>)> =
            main_variants(ModelTier::Mini).into_iter().map(|s| (s, None)).collect();
        let serial = eval_variants(&bench, &work, 5, 1);
        let par = eval_variants(&bench, &work, 5, 4);
        assert_eq!(serial.len(), work.len());
        assert_eq!(par, serial, "mixed per-problem + whole-variant tasks must reassemble exactly");
    }
}
