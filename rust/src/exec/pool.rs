//! Std-only work-stealing task pool (ADR-002).
//!
//! rayon/crossbeam are not in the offline vendor set, so this is built
//! from `std::thread::scope` plus per-worker `Mutex<VecDeque>` queues:
//! each worker starts with a contiguous chunk of the task index space,
//! pops its own queue from the front, and — once empty — steals from the
//! *back* of a victim's queue (classic Chase–Lev discipline, minus the
//! lock-free part: tasks here are whole agent sessions, microseconds to
//! milliseconds each, so a mutex per pop is noise).
//!
//! Determinism: tasks are identified by index, results land in their
//! index's slot, and every task derives its own RNG stream from its
//! identity (`Pcg32::derive`) rather than sharing a sequential generator —
//! so the output is a pure function of the task list, independent of
//! worker count, stealing order, and thread interleaving.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Resolve a requested job count: `0` means "use all available cores".
pub fn effective_jobs(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Apply `f` to every index in `0..n` using up to `jobs` worker threads
/// and return the results in index order. `jobs <= 1` runs inline with no
/// threads (the serial reference path).
///
/// Panic safety: a panicking task cannot deadlock or abort the pool.
/// Each task runs under `catch_unwind`; the first caught panic raises an
/// abort flag so workers stop pulling new tasks, every thread is still
/// joined (no detached worker outlives the scope), and the panic of the
/// *lowest* panicked task index is re-raised on the calling thread with
/// its original payload — so when one bad task is the cause, the caller
/// always sees that task's panic, not a per-interleaving coin flip.
pub fn parallel_map<R, F>(jobs: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let jobs = effective_jobs(jobs).min(n.max(1));
    if jobs <= 1 {
        return (0..n).map(f).collect();
    }

    // Contiguous initial chunks: worker w owns [w*n/jobs, (w+1)*n/jobs).
    let queues: Vec<Mutex<VecDeque<usize>>> = (0..jobs)
        .map(|w| Mutex::new((w * n / jobs..(w + 1) * n / jobs).collect()))
        .collect();
    let queues = &queues;
    let f = &f;
    let abort = &AtomicBool::new(false);

    type Panic = Box<dyn std::any::Any + Send + 'static>;
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut first_panic: Option<(usize, Panic)> = None;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..jobs)
            .map(|w| {
                s.spawn(move || {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    let mut panicked: Option<(usize, Panic)> = None;
                    while !abort.load(Ordering::Relaxed) {
                        // own queue first (front: cache-friendly order)…
                        let mut task = queues[w].lock().unwrap().pop_front();
                        // …then steal from the back of the first non-empty
                        // victim. No task ever re-enqueues, so a full idle
                        // scan means this worker is permanently done.
                        if task.is_none() {
                            for off in 1..jobs {
                                let v = (w + off) % jobs;
                                if let Some(t) = queues[v].lock().unwrap().pop_back() {
                                    task = Some(t);
                                    break;
                                }
                            }
                        }
                        match task {
                            // `f` is shared by reference across tasks, so it
                            // is not statically unwind-safe; we never call it
                            // again after a panic (abort flag + re-raise), so
                            // a torn invariant cannot be observed.
                            Some(i) => match catch_unwind(AssertUnwindSafe(|| f(i))) {
                                Ok(r) => done.push((i, r)),
                                Err(p) => {
                                    abort.store(true, Ordering::Relaxed);
                                    panicked = Some((i, p));
                                    break;
                                }
                            },
                            None => break,
                        }
                    }
                    (done, panicked)
                })
            })
            .collect();
        for h in handles {
            let (done, panicked) = h.join().expect("pool worker died outside a task");
            for (i, r) in done {
                out[i] = Some(r);
            }
            if let Some((i, p)) = panicked {
                match &first_panic {
                    Some((j, _)) if *j <= i => {}
                    _ => first_panic = Some((i, p)),
                }
            }
        }
    });
    if let Some((_, payload)) = first_panic {
        resume_unwind(payload);
    }
    out.into_iter().map(|r| r.expect("every task index produces a result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn matches_serial_in_order() {
        let serial: Vec<u64> = (0..257).map(|i| (i as u64) * (i as u64) + 7).collect();
        for jobs in [1, 2, 4, 9] {
            let par = parallel_map(jobs, 257, |i| (i as u64) * (i as u64) + 7);
            assert_eq!(par, serial, "jobs={jobs}");
        }
    }

    #[test]
    fn runs_every_task_exactly_once() {
        let n = 500;
        let count = AtomicUsize::new(0);
        let out = parallel_map(4, n, |i| {
            count.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(count.load(Ordering::SeqCst), n);
        assert_eq!(out, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn stealing_balances_skewed_tasks() {
        // all heavy tasks land in worker 0's initial chunk; with stealing
        // the wall clock must be well under the serial sum
        let heavy_iters = 3_000_000u64;
        let work = |iters: u64| {
            let mut x = 1u64;
            for i in 0..iters {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            x
        };
        let t0 = std::time::Instant::now();
        let serial: Vec<u64> =
            parallel_map(1, 8, |i| work(if i < 4 { heavy_iters } else { 1 }));
        let t_serial = t0.elapsed();
        let t1 = std::time::Instant::now();
        let par: Vec<u64> = parallel_map(4, 8, |i| work(if i < 4 { heavy_iters } else { 1 }));
        let t_par = t1.elapsed();
        assert_eq!(par, serial);
        // generous bound: stealing should reclaim most of the idle time,
        // but only when the machine actually has spare cores
        if effective_jobs(0) >= 2 {
            assert!(
                t_par < t_serial,
                "parallel ({t_par:?}) should beat serial ({t_serial:?}) on skewed load"
            );
        }
    }

    #[test]
    fn zero_and_tiny_inputs() {
        let empty: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(8, 1, |i| i + 1), vec![1]);
    }

    #[test]
    fn effective_jobs_resolution() {
        assert_eq!(effective_jobs(3), 3);
        assert!(effective_jobs(0) >= 1);
    }

    /// Silence the default panic-hook stderr spam while a test
    /// deliberately panics inside pool tasks; restores the hook on drop.
    struct QuietPanics;
    impl QuietPanics {
        fn new() -> QuietPanics {
            std::panic::set_hook(Box::new(|_| {}));
            QuietPanics
        }
    }
    impl Drop for QuietPanics {
        fn drop(&mut self) {
            let _ = std::panic::take_hook();
        }
    }

    #[test]
    fn panicking_task_propagates_original_payload() {
        let _quiet = QuietPanics::new();
        for jobs in [2, 4, 9] {
            let r = std::panic::catch_unwind(|| {
                parallel_map(jobs, 200, |i| {
                    if i == 137 {
                        panic!("task {i} exploded");
                    }
                    i
                })
            });
            let payload = r.expect_err("the task panic must propagate to the caller");
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .expect("panic! with args carries a String payload");
            assert_eq!(msg, "task 137 exploded", "jobs={jobs}");
        }
    }

    #[test]
    fn concurrent_panics_surface_a_genuine_task_panic() {
        // several tasks panic concurrently; whichever panics are caught
        // before the abort flag stops the pool, the caller must see the
        // original payload of a task that actually panicked
        let _quiet = QuietPanics::new();
        for _ in 0..10 {
            let r = std::panic::catch_unwind(|| {
                parallel_map(4, 64, |i| {
                    if i % 13 == 5 {
                        panic!("boom {i}");
                    }
                    i
                })
            });
            let payload = r.expect_err("must propagate");
            let msg = payload.downcast_ref::<String>().cloned().unwrap();
            let idx: usize = msg.strip_prefix("boom ").unwrap().parse().unwrap();
            assert_eq!(idx % 13, 5, "payload must come from a panicking task: {msg}");
        }
    }

    #[test]
    fn all_tasks_panicking_still_terminates() {
        let _quiet = QuietPanics::new();
        let r = std::panic::catch_unwind(|| {
            parallel_map(4, 32, |i| -> usize { panic!("{i}") })
        });
        assert!(r.is_err(), "must propagate one of the panics, not hang");
    }
}
