//! Std-only work-stealing task pool (ADR-002).
//!
//! rayon/crossbeam are not in the offline vendor set, so this is built
//! from `std::thread::scope` plus per-worker `Mutex<VecDeque>` queues:
//! each worker starts with a contiguous chunk of the task index space,
//! pops its own queue from the front, and — once empty — steals from the
//! *back* of a victim's queue (classic Chase–Lev discipline, minus the
//! lock-free part: tasks here are whole agent sessions, microseconds to
//! milliseconds each, so a mutex per pop is noise).
//!
//! Determinism: tasks are identified by index, results land in their
//! index's slot, and every task derives its own RNG stream from its
//! identity (`Pcg32::derive`) rather than sharing a sequential generator —
//! so the output is a pure function of the task list, independent of
//! worker count, stealing order, and thread interleaving.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Resolve a requested job count: `0` means "use all available cores".
pub fn effective_jobs(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Apply `f` to every index in `0..n` using up to `jobs` worker threads
/// and return the results in index order. `jobs <= 1` runs inline with no
/// threads (the serial reference path). Panics in `f` propagate.
pub fn parallel_map<R, F>(jobs: usize, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let jobs = effective_jobs(jobs).min(n.max(1));
    if jobs <= 1 {
        return (0..n).map(f).collect();
    }

    // Contiguous initial chunks: worker w owns [w*n/jobs, (w+1)*n/jobs).
    let queues: Vec<Mutex<VecDeque<usize>>> = (0..jobs)
        .map(|w| Mutex::new((w * n / jobs..(w + 1) * n / jobs).collect()))
        .collect();
    let queues = &queues;
    let f = &f;

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..jobs)
            .map(|w| {
                s.spawn(move || {
                    let mut done: Vec<(usize, R)> = Vec::new();
                    loop {
                        // own queue first (front: cache-friendly order)…
                        let mut task = queues[w].lock().unwrap().pop_front();
                        // …then steal from the back of the first non-empty
                        // victim. No task ever re-enqueues, so a full idle
                        // scan means this worker is permanently done.
                        if task.is_none() {
                            for off in 1..jobs {
                                let v = (w + off) % jobs;
                                if let Some(t) = queues[v].lock().unwrap().pop_back() {
                                    task = Some(t);
                                    break;
                                }
                            }
                        }
                        match task {
                            Some(i) => done.push((i, f(i))),
                            None => break,
                        }
                    }
                    done
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("pool worker panicked") {
                out[i] = Some(r);
            }
        }
    });
    out.into_iter().map(|r| r.expect("every task index produces a result")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn matches_serial_in_order() {
        let serial: Vec<u64> = (0..257).map(|i| (i as u64) * (i as u64) + 7).collect();
        for jobs in [1, 2, 4, 9] {
            let par = parallel_map(jobs, 257, |i| (i as u64) * (i as u64) + 7);
            assert_eq!(par, serial, "jobs={jobs}");
        }
    }

    #[test]
    fn runs_every_task_exactly_once() {
        let n = 500;
        let count = AtomicUsize::new(0);
        let out = parallel_map(4, n, |i| {
            count.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(count.load(Ordering::SeqCst), n);
        assert_eq!(out, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn stealing_balances_skewed_tasks() {
        // all heavy tasks land in worker 0's initial chunk; with stealing
        // the wall clock must be well under the serial sum
        let heavy_iters = 3_000_000u64;
        let work = |iters: u64| {
            let mut x = 1u64;
            for i in 0..iters {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            x
        };
        let t0 = std::time::Instant::now();
        let serial: Vec<u64> =
            parallel_map(1, 8, |i| work(if i < 4 { heavy_iters } else { 1 }));
        let t_serial = t0.elapsed();
        let t1 = std::time::Instant::now();
        let par: Vec<u64> = parallel_map(4, 8, |i| work(if i < 4 { heavy_iters } else { 1 }));
        let t_par = t1.elapsed();
        assert_eq!(par, serial);
        // generous bound: stealing should reclaim most of the idle time,
        // but only when the machine actually has spare cores
        if effective_jobs(0) >= 2 {
            assert!(
                t_par < t_serial,
                "parallel ({t_par:?}) should beat serial ({t_serial:?}) on skewed load"
            );
        }
    }

    #[test]
    fn zero_and_tiny_inputs() {
        let empty: Vec<usize> = parallel_map(4, 0, |i| i);
        assert!(empty.is_empty());
        assert_eq!(parallel_map(8, 1, |i| i + 1), vec![1]);
    }

    #[test]
    fn effective_jobs_resolution() {
        assert_eq!(effective_jobs(3), 3);
        assert!(effective_jobs(0) >= 1);
    }
}
