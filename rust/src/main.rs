//! `repro` — the L3 coordinator CLI.
//!
//! ```text
//! repro exp <fig3..fig14|tab4|all> [--out DIR] [--seed N]   regenerate paper artifacts
//! repro sol <problem-id>                                     SOL report (Appendix A.2)
//! repro dsl compile <file|->  [--dims MxNxK]                 compile µCUTLASS source
//! repro dsl coverage                                         Table 1 coverage matrix
//! repro lint <file|-> [--json] [--arch A] [--deny-warnings]  static analysis (ADR-009)
//! repro run --tier T [--dsl] [--sol orch|prompt] [--prune] [--problems IDs] [--seed N]
//! repro validate [--artifacts DIR] [--problem NAME] [--seed N]
//! repro schedule --tier T [--eps PCT] [--window W] [--seed N]
//! repro sweep [--tier T] [--trace PATH [--live]] [--jobs N] [--out FILE]
//! repro record <exp|run|schedule|sweep> ... --trace PATH           record measurements
//! repro replay <exp|run|schedule|sweep> ... --trace PATH [--live]  replay them offline
//! repro serve --workers N [--deadline-ms D] [--retries R] ...      fleet coordinator (ADR-007)
//! repro worker [--faults SPEC] [--fault-offset N]                  one fleet worker (internal)
//! repro <exp|run|schedule|sweep> ... --cache PATH [--offline]      persistent eval cache (ADR-008)
//! repro <serve|sweep|schedule> ... --journal PATH [--resume]       crash-safe runs (ADR-010)
//! repro cache <stats|export|import|compact|repair|gc> ...          inspect / bridge / maintain a cache store
//! repro list                                                 list the 59 problems
//! ```
//!
//! (clap is not in the offline vendor set; argument parsing is hand-rolled.)

use std::collections::HashMap;
use std::io::Read;
use std::process::ExitCode;

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::{ModelTier, RunLog};
use ucutlass_repro::eval::manifest::{suite_merge, suite_shard, SuiteShard, SuiteWork};
use ucutlass_repro::eval::trace::{trace_session, TraceMode};
use ucutlass_repro::eval::{DynEvaluator, TraceMonitor};
use ucutlass_repro::exec;
use ucutlass_repro::experiments::figures::{self, ExpCtx};
use ucutlass_repro::fleet::{
    run_fleet_journaled, subprocess_worker_factory, worker_loop, EventLog, FaultPlan,
    FleetConfig, WorkerOpts,
};
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::journal::{scan_journal, LeaseKeeper, LeaseMonitor, RunJournal};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::kernelbench;
use ucutlass_repro::metrics;
use ucutlass_repro::report::table;
use ucutlass_repro::scheduler::{self, Policy};
use ucutlass_repro::sol;
use ucutlass_repro::store::{
    self, cache_session, CacheSessionMode, EvalStore, StoreMonitor,
};
use ucutlass_repro::util::fnv64;
use ucutlass_repro::util::json::Json;
use ucutlass_repro::{analyze, dsl, runtime};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Split args into positionals and `--flag value` options.
fn parse_opts(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            opts.insert(name.to_string(), val);
        } else {
            pos.push(args[i].clone());
        }
        i += 1;
    }
    (pos, opts)
}

/// Parse an optional `--name value` flag, with a default when absent.
/// Unparseable values are in-band errors, not silent defaults.
fn opt_parse<T: std::str::FromStr>(
    opts: &HashMap<String, String>,
    name: &str,
    default: T,
) -> Result<T, String> {
    match opts.get(name) {
        None => Ok(default),
        Some(s) => s.parse().map_err(|_| format!("--{name}: invalid value `{s}`")),
    }
}

/// Parse a required `--name value` flag.
fn opt_require<T: std::str::FromStr>(
    opts: &HashMap<String, String>,
    name: &str,
    usage: &str,
) -> Result<T, String> {
    match opts.get(name) {
        None => Err(format!("--{name} required ({usage})")),
        Some(s) => s.parse().map_err(|_| format!("--{name}: invalid value `{s}`")),
    }
}

fn tier_of(s: &str) -> Result<ModelTier, String> {
    match s {
        "mini" | "gpt-5-mini" => Ok(ModelTier::Mini),
        "mid" | "gpt-5" => Ok(ModelTier::Mid),
        "max" | "gpt-5.2" => Ok(ModelTier::Max),
        other => Err(format!("unknown tier `{other}` (mini|mid|max)")),
    }
}

fn run(args: &[String]) -> Result<(), String> {
    let (pos, opts) = parse_opts(args);
    let seed: u64 = opt_parse(&opts, "seed", 12345)?;
    // --jobs N worker threads for suite evaluation (0 = all cores).
    // Results are bit-identical at any job count (ADR-002).
    let jobs: usize = opt_parse(&opts, "jobs", 1)?;
    let cmd = pos.first().map(String::as_str);
    if opts.contains_key("trace")
        && !matches!(cmd, Some("record") | Some("replay") | Some("sweep"))
    {
        return Err(
            "--trace is only meaningful under `repro record` / `repro replay` / `repro sweep`"
                .into(),
        );
    }
    if opts.contains_key("live") && !matches!(cmd, Some("replay") | Some("sweep")) {
        return Err("--live is only meaningful under `repro replay` / `repro sweep`".into());
    }
    if opts.contains_key("cache")
        && !matches!(
            cmd,
            Some("exp") | Some("run") | Some("schedule") | Some("sweep") | Some("serve")
                | Some("worker")
        )
    {
        return Err(
            "--cache is only meaningful under `repro exp|run|schedule|sweep|serve|worker` \
             (inspect a store with `repro cache stats PATH`)"
                .into(),
        );
    }
    if opts.contains_key("offline") && !opts.contains_key("cache") {
        return Err("--offline needs --cache PATH (serve this run entirely from the store)".into());
    }
    if opts.contains_key("cache") && opts.contains_key("trace") {
        return Err(
            "--cache and --trace are mutually exclusive oracles (bridge between them with \
             `repro cache export|import`)"
                .into(),
        );
    }
    if opts.contains_key("journal")
        && !matches!(cmd, Some("serve") | Some("sweep") | Some("schedule") | Some("cache"))
    {
        return Err(
            "--journal is only meaningful under `repro serve|sweep|schedule` (crash-safe \
             runs, ADR-010) and `repro cache gc`"
                .into(),
        );
    }
    if opts.contains_key("resume")
        && !(opts.contains_key("journal")
            && matches!(cmd, Some("serve") | Some("sweep") | Some("schedule")))
    {
        return Err(
            "--resume needs --journal PATH under `repro serve|sweep|schedule` (continue \
             that journaled run)"
                .into(),
        );
    }
    // `--cache` on exp/run/schedule/sweep wraps the subcommand in a cache
    // session the way `repro record`/`replay` wrap it in a trace session
    if opts.contains_key("cache")
        && matches!(cmd, Some("exp") | Some("run") | Some("schedule") | Some("sweep"))
    {
        return cmd_cached(&pos, &opts, seed, jobs);
    }
    match cmd {
        Some("exp") => cmd_exp(&pos, &opts, seed, jobs, None),
        Some("sol") => cmd_sol(&pos),
        Some("dsl") => cmd_dsl(&pos, &opts),
        Some("lint") => cmd_lint(&pos, &opts),
        Some("run") => cmd_run(&pos, &opts, seed, jobs, None),
        Some("validate") => cmd_validate(&opts, seed),
        Some("schedule") => cmd_schedule(&opts, seed, jobs, None),
        Some("sweep") => cmd_sweep(&opts, seed, jobs, None),
        Some("record") => cmd_traced(TraceMode::Record, &pos, &opts, seed, jobs),
        Some("replay") => {
            let mode = if opts.contains_key("live") {
                TraceMode::ReplayExtend
            } else {
                TraceMode::ReplayStrict
            };
            cmd_traced(mode, &pos, &opts, seed, jobs)
        }
        Some("shard") => cmd_shard(&opts, seed),
        Some("merge") => cmd_merge(&pos, &opts),
        Some("serve") => cmd_serve(&opts, seed),
        Some("worker") => cmd_worker(&opts),
        Some("cache") => cmd_cache(&pos, &opts),
        Some("list") => cmd_list(),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

/// `repro record <exp|run|schedule|sweep> … --trace PATH` /
/// `repro replay <exp|run|schedule|sweep> … --trace PATH [--live]`
/// (ADR-004): run the wrapped subcommand with a recording or
/// trace-serving oracle installed, then report the trace outcome —
/// strict-replay misses and recording I/O failures exit nonzero.
fn cmd_traced(
    mode: TraceMode,
    pos: &[String],
    opts: &HashMap<String, String>,
    seed: u64,
    jobs: usize,
) -> Result<(), String> {
    const USAGE: &str = "usage: repro record|replay <exp|run|schedule|sweep> [...] --trace PATH";
    let path = opts.get("trace").ok_or(format!("--trace PATH required ({USAGE})"))?;
    // `--trace` with no following value parses as the sentinel "true" —
    // reject it rather than silently recording into a file named `true`
    if path == "true" {
        return Err(format!("--trace needs a file path ({USAGE})"));
    }
    // validate the wrapped subcommand BEFORE touching the trace file, so
    // a typo cannot clobber an existing recording (the recorder also
    // creates its file lazily, on the first recorded measurement)
    let inner = &pos[1..];
    let sub = match inner.first().map(String::as_str) {
        Some(s @ ("exp" | "run" | "schedule" | "sweep")) => s,
        Some(other) => {
            return Err(format!("record/replay cannot wrap `{other}` (exp|run|schedule|sweep)"))
        }
        None => return Err(USAGE.into()),
    };
    let (oracle, monitor) = trace_session(mode, path)?;
    match sub {
        "exp" => cmd_exp(inner, opts, seed, jobs, Some(oracle))?,
        "run" => cmd_run(inner, opts, seed, jobs, Some(oracle))?,
        // sweep gets the monitor too: it must refuse to persist its --out
        // grid when the trace had misses or I/O errors
        "sweep" => {
            cmd_sweep(opts, seed, jobs, Some((oracle, OracleMonitor::Trace(monitor.clone()))))?
        }
        _ => cmd_schedule(opts, seed, jobs, Some(oracle))?,
    }
    println!("{}", monitor.summary());
    monitor.check()
}

/// The session monitor of whichever oracle wraps a subcommand — a traced
/// run's `TraceMonitor` or a cached run's `StoreMonitor`. `cmd_sweep`
/// only needs the shared verdict surface (summary + in-band check before
/// persisting `--out`), so it takes this instead of a concrete monitor.
enum OracleMonitor {
    Trace(TraceMonitor),
    Store(StoreMonitor),
}

impl OracleMonitor {
    fn summary(&self) -> String {
        match self {
            OracleMonitor::Trace(m) => m.summary(),
            OracleMonitor::Store(m) => m.summary(),
        }
    }

    fn check(&self) -> Result<(), String> {
        match self {
            OracleMonitor::Trace(m) => m.check(),
            OracleMonitor::Store(m) => m.check(),
        }
    }
}

/// `repro <exp|run|schedule|sweep> … --cache PATH [--offline]`
/// (ADR-008): run the subcommand with the persistent eval store layered
/// over the live backend. Without `--offline` the session is
/// write-through — hits are served from the store, misses are measured
/// live and appended, so the next run (any process, any fleet node)
/// never pays for them again. With `--offline` there is no live backend
/// at all: a store miss answers in-band and fails the session check,
/// proving the run was reproduced entirely from the cache.
fn cmd_cached(
    pos: &[String],
    opts: &HashMap<String, String>,
    seed: u64,
    jobs: usize,
) -> Result<(), String> {
    const USAGE: &str = "usage: repro <exp|run|schedule|sweep> [...] --cache PATH [--offline]";
    let path = opts.get("cache").expect("dispatcher checked --cache");
    if path == "true" {
        return Err(format!("--cache needs a file path ({USAGE})"));
    }
    let mode = if opts.contains_key("offline") {
        CacheSessionMode::Offline
    } else {
        CacheSessionMode::WriteThrough
    };
    let (oracle, monitor) = cache_session(mode, path.into())?;
    match pos.first().map(String::as_str) {
        Some("exp") => cmd_exp(pos, opts, seed, jobs, Some(oracle))?,
        Some("run") => cmd_run(pos, opts, seed, jobs, Some(oracle))?,
        // sweep gets the monitor: a miss-poisoned grid must fail before
        // --out is persisted, exactly as in the traced path
        Some("sweep") => {
            cmd_sweep(opts, seed, jobs, Some((oracle, OracleMonitor::Store(monitor.clone()))))?
        }
        _ => cmd_schedule(opts, seed, jobs, Some(oracle))?,
    }
    // the oracle was dropped inside the subcommand (Bench owns it), so
    // the store's index + trailer are on disk before we report
    println!("{}", monitor.summary());
    monitor.check()
}

/// `repro cache <stats|export|import|compact>`: inspect and maintain
/// binary eval stores. `export`/`import` bridge losslessly to the JSONL
/// v2 trace, which stays the diagnostic/interchange format (floats
/// travel as shortest-roundtrip decimals that reparse bit-identically).
fn cmd_cache(pos: &[String], opts: &HashMap<String, String>) -> Result<(), String> {
    const USAGE: &str = "usage: repro cache stats STORE | cache export STORE TRACE | \
                         cache import TRACE STORE | cache compact STORE --out STORE2 | \
                         cache repair STORE --out STORE2 | \
                         cache gc STORE --max-bytes N --out STORE2 [--journal JOURNAL]";
    match pos.get(1).map(String::as_str) {
        Some("stats") => {
            let path = pos.get(2).ok_or(format!("cache stats STORE ({USAGE})"))?;
            let store = EvalStore::open(path)?;
            let mut pass = 0u64;
            let mut fail = 0u64;
            let mut by_kind: std::collections::BTreeMap<String, u64> = Default::default();
            let mut problems: std::collections::BTreeSet<usize> = Default::default();
            for key in store.keys() {
                let (req, resp) = store.get_pair(key)?.expect("indexed key has a record");
                if resp.pass {
                    pass += 1;
                } else {
                    fail += 1;
                }
                *by_kind.entry(format!("{:?}", req.kind)).or_insert(0) += 1;
                problems.insert(req.problem);
            }
            println!("store {path}: format v{}", store::STORE_VERSION);
            println!(
                "  {} record(s) ({pass} pass, {fail} fail) across {} problem(s)",
                store.len(),
                problems.len()
            );
            for (kind, n) in &by_kind {
                println!("  {kind}: {n}");
            }
            println!(
                "  {} bytes on disk; open reads {} bytes (header + index + trailer), \
                 no JSON parsed",
                store.file_bytes(),
                store.open_bytes()
            );
            println!("  all record checksums verified");
            Ok(())
        }
        Some("export") => {
            let src = pos.get(2).ok_or(format!("cache export STORE TRACE ({USAGE})"))?;
            let dst = pos.get(3).ok_or(format!("cache export STORE TRACE ({USAGE})"))?;
            let store = EvalStore::open(src)?;
            let n = store::export_jsonl(&store, dst)?;
            println!(
                "exported {n} record(s) from {src} to JSONL v2 trace {dst} (replayable with \
                 `repro replay … --trace {dst}`)"
            );
            Ok(())
        }
        Some("import") => {
            let src = pos.get(2).ok_or(format!("cache import TRACE STORE ({USAGE})"))?;
            let dst = pos.get(3).ok_or(format!("cache import TRACE STORE ({USAGE})"))?;
            let n = store::import_jsonl(src, dst)?;
            println!("imported {n} record(s) from JSONL trace {src} into store {dst}");
            Ok(())
        }
        Some("compact") => {
            let src = pos.get(2).ok_or(format!("cache compact STORE --out STORE2 ({USAGE})"))?;
            let dst = match opts.get("out") {
                Some(p) if p != "true" => p,
                _ => return Err(format!("cache compact needs --out STORE2 ({USAGE})")),
            };
            let store = EvalStore::open(src)?;
            let (n, bytes_in, bytes_out) = store::compact_store(&store, dst)?;
            println!(
                "compacted {src} ({bytes_in} bytes) into {dst} ({bytes_out} bytes): \
                 {n} record(s), every checksum verified"
            );
            Ok(())
        }
        // `cache repair` (ADR-010): recover the checksummed-valid record
        // prefix of a store torn mid-append or mid-finish — where open()
        // correctly refuses in-band — and rebuild index + trailer at dst.
        // On an intact store this is exactly `cache compact`.
        Some("repair") => {
            let src = pos.get(2).ok_or(format!("cache repair STORE --out STORE2 ({USAGE})"))?;
            let dst = match opts.get("out") {
                Some(p) if p != "true" => p,
                _ => return Err(format!("cache repair needs --out STORE2 ({USAGE})")),
            };
            let rep = store::repair_store(src, dst)?;
            println!(
                "repaired {src} ({} bytes) into {dst} ({} bytes): {} record(s) recovered, \
                 {} byte(s) past the last intact record dropped (index + trailer rebuilt)",
                rep.bytes_in, rep.bytes_out, rep.records, rep.dropped_bytes
            );
            if let Some(why) = &rep.stopped {
                println!("  record scan stopped at: {why}");
            }
            Ok(())
        }
        // `cache gc` (ADR-010): evict least-recently-served records until
        // the rewrite fits --max-bytes. Recency comes from the advisory
        // `<store>.lru` sidecar cached sessions append; an under-budget
        // store rewrites byte-identically. With --journal the GC refuses,
        // in-band, to run against a journal of a still-active run.
        Some("gc") => {
            const GC: &str = "cache gc STORE --max-bytes N --out STORE2 [--journal JOURNAL]";
            let src = pos.get(2).ok_or(format!("{GC} ({USAGE})"))?;
            let max_bytes: u64 = opt_require(opts, "max-bytes", GC)?;
            let dst = match opts.get("out") {
                Some(p) if p != "true" => p,
                _ => return Err(format!("cache gc needs --out STORE2 ({USAGE})")),
            };
            if let Some(jp) = opts.get("journal") {
                if jp == "true" {
                    return Err(format!("--journal needs a file path ({GC})"));
                }
                let scan = scan_journal(jp)?;
                let done = scan
                    .records
                    .iter()
                    .any(|r| r.get("kind").and_then(|k| k.as_str()) == Some("done"));
                if !done {
                    return Err(format!(
                        "cache gc: journal {jp} records an active (not done) run — finish \
                         or --resume it first, or gc without --journal"
                    ));
                }
            }
            let store = EvalStore::open(src)?;
            let recency =
                store::read_lru_sidecar(store::lru_sidecar_path(std::path::Path::new(src)));
            let rep = store::gc_store(&store, max_bytes, dst, &recency, &Default::default())?;
            println!(
                "gc {src} ({} bytes) into {dst} ({} bytes, budget {max_bytes}): kept \
                 {} record(s), evicted {} least-recently-served",
                rep.bytes_in, rep.bytes_out, rep.kept, rep.evicted
            );
            if rep.evicted == 0 {
                println!("  under budget: output is the identity rewrite (same records, same order)");
            }
            Ok(())
        }
        _ => Err(USAGE.into()),
    }
}

const HELP: &str = "\
repro — µCUTLASS + SOL-guidance reproduction (see README.md)

  repro exp <fig3|fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11|fig12|fig13|fig14|tab4|ext1|ext2|all>
            [--out results] [--seed N] [--jobs N]
  repro sol <problem-id>               e.g. repro sol L1-1
  repro dsl compile <file|->           [--dims MxNxK]
  repro dsl coverage
  repro lint <file|->                  [--json] [--arch A] [--deny-warnings]
  repro run --tier <mini|mid|max> [--dsl] [--sol <orch|prompt>] [--prune]
            [--problems L1-1,L2-76] [--seed N] [--jobs N]
  repro validate [--artifacts artifacts] [--problem NAME] [--seed N]
  repro schedule --tier <mini|mid|max> [--eps 100] [--window 8] [--seed N] [--jobs N]
            [--journal PATH [--resume]]
  repro sweep [--tier <mini|mid|max>] [--trace PATH [--live]] [--seed N]
            [--jobs N] [--journal PATH [--resume]] [--out FILE]
  repro record <exp|run|schedule|sweep> [...] --trace PATH
  repro replay <exp|run|schedule|sweep> [...] --trace PATH [--live]
  repro shard --index I --of N --tier <mini|mid|max> [--dsl] [--sol <orch|prompt>]
            [--seed N] [--out FILE]
  repro merge <shard.json>... [--out FILE]
  repro serve --workers N [--deadline-ms 30000] [--retries 3] [--quarantine-after 3]
            [--shards S] [--eps 100] --tier <mini|mid|max> [--dsl] [--sol <orch|prompt>]
            [--seed N] [--faults \"0=0:crash;1=2:garbage\"] [--events FILE]
            [--journal PATH [--resume]] [--out FILE]
  repro worker [--faults ORD:FAULT,..] [--fault-offset N]   (spawned by serve)
  repro <exp|run|schedule|sweep|serve> [...] --cache PATH [--offline]
  repro cache stats STORE
  repro cache export STORE TRACE.jsonl
  repro cache import TRACE.jsonl STORE
  repro cache compact STORE --out STORE2
  repro cache repair STORE --out STORE2
  repro cache gc STORE --max-bytes N --out STORE2 [--journal JOURNAL]
  repro list

  --jobs N fans (variant, problem, seed) tasks across N worker threads
  (0 = all cores); output is bit-identical to --jobs 1.
  shard/merge split the same evaluation across processes/machines: run
  `repro shard --index I --of N ...` once per I with identical settings,
  then `repro merge shard_*.json` — the merged log is bit-identical to a
  single-process `repro run` of the same variant and seed.
  record/replay persist every measurement of a run to a JSONL trace and
  re-run experiments offline from it (ADR-004): `repro record run --tier
  mini --trace t.jsonl`, then `repro replay run --tier mini --trace
  t.jsonl` reproduces the run field-for-field without touching the
  analytic backend (strict; a trace miss fails the command). --live falls
  through to the live backend on misses and extends the trace.
  serve runs the same evaluation across a fault-tolerant fleet of `repro
  worker` subprocesses (ADR-007): per-shard deadlines with exponential
  backoff retries, straggler re-issue (first completion wins), worker
  quarantine after consecutive failures, SOL-aware admission ordering,
  and an incremental merge whose output is field-for-field identical to a
  single-process run. --faults scripts deterministic worker misbehavior
  per slot (crash|hang|truncate|garbage|wrong-version|duplicate) for the
  fault-injection harness; --events streams the coordinator's decision
  log (assign/retry/quarantine/merge...) as JSONL.
  --cache PATH layers the persistent content-addressed eval store over
  the live backend (ADR-008): hits are served from the store (binary
  format v1 — the store opens by reading its key->offset index, no JSON
  parsed), misses are measured live and written through, so no (problem,
  config, seed) measurement is ever paid for twice across runs, users,
  or fleet nodes. --offline removes the live backend entirely: a miss
  answers in-band and fails the command, proving the run was reproduced
  from the cache alone. Under serve, the coordinator opens the store
  read-only and forwards --cache to every worker (fleets consume stores;
  recording runs produce them). `repro cache` inspects a store (stats),
  bridges it losslessly to/from the JSONL v2 diagnostic format
  (export/import; floats survive bit-identically), and rewrites it
  densely with full verification (compact).
  --journal PATH makes serve/sweep/schedule crash-safe (ADR-010): every
  landed shard, exhausted session pass, and stop decision is appended
  (checksummed, fsynced) to a write-ahead journal before it is acted on,
  and a lease file beside the journal is heartbeat so workers orphaned
  by a coordinator crash self-terminate within one deadline. After kill
  -9 at ANY point, the same command plus --resume recovers the valid
  journal prefix (a torn tail is dropped; corruption is an in-band
  error) and continues to output byte-identical to the uninterrupted
  run, re-measuring no landed key. `repro cache repair` recovers the
  checksummed-valid record prefix of a store torn mid-write (rebuilding
  index + trailer); `repro cache gc` evicts least-recently-served
  records to fit --max-bytes, is the identity on an under-budget store,
  and refuses to run against a journal of a still-active run.
  sweep replays the full 72-policy fig8/fig9 scheduler grid from ONE
  exhausted session pass per variant (ADR-005): sessions are driven once
  to budget exhaustion, every (eps, w) stopping rule is applied offline,
  and each policy's reported outcome is field-for-field identical to a
  per-policy `repro schedule` run. With --trace PATH the pass is served
  from a recorded trace (zero live evaluations; record one with `repro
  record sweep --trace PATH`); --out FILE writes machine-readable JSON.";

fn cmd_exp(
    pos: &[String],
    opts: &HashMap<String, String>,
    seed: u64,
    jobs: usize,
    oracle: Option<Box<DynEvaluator>>,
) -> Result<(), String> {
    let which = pos.get(1).map(String::as_str).unwrap_or("all");
    let out = opts.get("out").cloned().unwrap_or_else(|| "results".into());
    let mut ctx = ExpCtx::new(&out, seed).with_jobs(jobs);
    if let Some(o) = oracle {
        ctx = ctx.with_oracle(o);
    }
    let text = match which {
        "fig3" => figures::fig3(&mut ctx),
        "fig4" => figures::fig4(&mut ctx),
        "fig5" => figures::fig5(&mut ctx),
        "fig6" => figures::fig6(&mut ctx),
        "fig7" => figures::fig7(&mut ctx),
        "fig8" => figures::fig8(&mut ctx),
        "fig9" => figures::fig9(&mut ctx),
        "fig10" => figures::fig10(&mut ctx),
        "fig11" => figures::fig11(&mut ctx),
        "fig12" => figures::fig12(&mut ctx),
        "fig13" => figures::fig13(&mut ctx),
        "fig14" => figures::fig14(&mut ctx),
        "tab2" | "variants" => figures::tab2(&mut ctx),
        "tab4" => figures::tab4(&mut ctx),
        "ext1" => figures::ext1_online_integrity(&mut ctx),
        "ext2" => figures::ext2_adaptive_hybrid(&mut ctx),
        "all" => figures::run_all(&mut ctx),
        other => return Err(format!("unknown experiment `{other}`")),
    };
    println!("{text}");
    println!("(artifacts written to {out}/)");
    Ok(())
}

fn cmd_sol(pos: &[String]) -> Result<(), String> {
    let id = pos.get(1).ok_or("usage: repro sol <problem-id>")?;
    let problems = kernelbench::suite();
    let idx = kernelbench::find(&problems, id).ok_or(format!("unknown problem {id}"))?;
    let analysis = sol::analyze(&problems[idx], &sol::H100_SXM);
    println!("{}", sol::render_report(&problems[idx], &analysis));
    Ok(())
}

/// `repro lint <file|-> [--json] [--arch A] [--deny-warnings]` (ADR-009).
///
/// Exit codes: 0 = clean (or warnings/notes only), 1–100 = number of Deny
/// diagnostics (clamped; `--deny-warnings` escalates Warn to Deny, Notes
/// never escalate), 101 = the program does not compile at all.
fn cmd_lint(pos: &[String], opts: &HashMap<String, String>) -> Result<(), String> {
    let src = match pos.get(1).map(String::as_str) {
        Some("-") | None => {
            let mut s = String::new();
            std::io::stdin().read_to_string(&mut s).map_err(|e| e.to_string())?;
            s
        }
        Some(path) => std::fs::read_to_string(path).map_err(|e| e.to_string())?,
    };
    let arch = match opts.get("arch") {
        None => None,
        Some(a) => Some(
            dsl::Arch::parse(a).ok_or_else(|| format!("--arch: unknown architecture `{a}`"))?,
        ),
    };
    let json = opts.contains_key("json");
    let deny_warnings = opts.contains_key("deny-warnings");
    match analyze::analyze_source(&src, arch) {
        Err(e) => {
            // Compiler rejection: one coded error, same JSON schema as the
            // analyzer's diagnostics (E-codes and A/C-codes share a
            // namespace), distinct exit code so CI can tell "does not
            // compile" from "lints dirty".
            if json {
                let mut o = Json::obj();
                o.set("ok", false)
                    .set("deny_count", 1u64)
                    .set("diagnostics", Json::Arr(vec![e.to_json()]));
                println!("{}", o.to_pretty());
            } else {
                eprintln!("{e}");
            }
            std::process::exit(101);
        }
        Ok(diags) => {
            let denies = analyze::deny_count(&diags, deny_warnings);
            if json {
                let mut o = Json::obj();
                o.set("ok", denies == 0)
                    .set("deny_count", denies as u64)
                    .set(
                        "diagnostics",
                        Json::Arr(diags.iter().map(|d| d.to_json()).collect()),
                    );
                println!("{}", o.to_pretty());
            } else {
                for d in &diags {
                    println!("{}", d.render(&src));
                }
                println!(
                    "{} diagnostic(s), {} deny{}",
                    diags.len(),
                    denies,
                    if deny_warnings { " (warnings denied)" } else { "" }
                );
            }
            if denies > 0 {
                std::process::exit(denies.min(100) as i32);
            }
            Ok(())
        }
    }
}

fn cmd_dsl(pos: &[String], opts: &HashMap<String, String>) -> Result<(), String> {
    match pos.get(1).map(String::as_str) {
        Some("compile") => {
            let src = match pos.get(2).map(String::as_str) {
                Some("-") | None => {
                    let mut s = String::new();
                    std::io::stdin().read_to_string(&mut s).map_err(|e| e.to_string())?;
                    s
                }
                Some(path) => std::fs::read_to_string(path).map_err(|e| e.to_string())?,
            };
            let compiled = if let Some(dims) = opts.get("dims") {
                let d: Vec<u64> = dims.split('x').filter_map(|x| x.parse().ok()).collect();
                if d.len() != 3 {
                    return Err("--dims expects MxNxK".into());
                }
                dsl::compile_bound(&src, (d[0], d[1], d[2]))
            } else {
                dsl::compile(&src)
            };
            match compiled {
                Ok(c) => {
                    println!("// {}\n{}", c.header_name, c.header);
                    let k = c.plan.primary();
                    println!(
                        "// plan: {} on {} tile {}x{}x{} {} stages={} smem={}B hash={}",
                        k.family, k.arch, k.tile.m, k.tile.n, k.tile.k, k.dtype_input,
                        k.stages, k.smem_bytes, c.plan.config_hash
                    );
                    Ok(())
                }
                Err(e) => Err(e.to_string()),
            }
        }
        Some("coverage") => {
            // Table 1 coverage matrix
            let rows = vec![
                vec!["GEMM".into(), "SM70+".into(), "—".into()],
                vec!["Grouped GEMM".into(), "SM80+".into(), "—".into()],
                vec!["Conv2d".into(), "SM70+".into(), "NHWC".into()],
                vec!["Conv3d".into(), "SM70+".into(), "NDHWC".into()],
                vec!["Conv3d wgrad".into(), "SM70–89".into(), "SM90+ rejected".into()],
                vec!["Conv1d".into(), "SM70+".into(), "lowered to Conv2d, H=1".into()],
                vec!["Depthwise Conv".into(), "SM70–89; SM90+*".into(), "CuTe backend on SM90+".into()],
                vec!["Grouped Conv".into(), "SM80–89".into(), "—".into()],
            ];
            println!("{}", table(&["operation family", "arch support", "notes"], &rows));
            let feats = vec![
                vec![".with_dtype/.with_arch/.with_alignment/.with_stages".into(), "SM70+".into()],
                vec![".with_tile / .with_swizzle / .with_iterator / .with_split_k".into(), "SM70–89".into()],
                vec![".with_threadblockshape / .with_cluster / .with_scheduler".into(), "SM90+".into()],
                vec![".with_operand_swap(true)".into(), "SM90+ FP32 GEMM, M==N".into()],
                vec!["pipeline/transpose + fused dtype conversion".into(), "SM70+".into()],
                vec!["custom() epilogues".into(), "SM90a".into()],
            ];
            println!("{}", table(&["feature / binding", "arch support"], &feats));
            Ok(())
        }
        _ => Err("usage: repro dsl <compile|coverage>".into()),
    }
}

/// Build the single-variant spec `repro run` and `repro shard` share from
/// `--tier` / `--dsl` / `--sol`.
fn spec_from_opts(opts: &HashMap<String, String>) -> Result<VariantSpec, String> {
    let tier = tier_of(opts.get("tier").map(String::as_str).unwrap_or("mini"))?;
    let dsl_on = opts.contains_key("dsl");
    let controller = match opts.get("sol").map(String::as_str) {
        Some("orch") => ControllerKind::OrchestratedSol,
        Some("prompt") => ControllerKind::InPromptSol,
        None => ControllerKind::Mi,
        Some(other) => return Err(format!("unknown --sol `{other}` (orch|prompt)")),
    };
    let spec = VariantSpec::new(controller, dsl_on, tier);
    // static-analyzer pruning (ADR-009): skip provably non-improving trials
    Ok(if opts.contains_key("prune") { spec.with_prune() } else { spec })
}

/// The per-problem summary table `repro run` and `repro merge` share.
fn print_log(bench: &Bench, log: &RunLog, review_seed: u64, selected: &[usize]) {
    let pipeline = IntegrityPipeline::default();
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for &i in selected {
        let run = &log.runs[i];
        let sp = pipeline.filtered_speedup(run, review_seed).unwrap_or(1.0);
        speedups.push(sp);
        rows.push(vec![
            bench.problems[i].id.to_string(),
            bench.problems[i].name.into(),
            format!("{:.3}", run.t_ref_ms),
            run.best_time_ms().map(|t| format!("{t:.3}")).unwrap_or("-".into()),
            format!("{sp:.2}x"),
            format!("{:.3}", run.t_sol_fp16_ms),
            format!("{}", run.total_tokens()),
        ]);
    }
    println!("variant: {}", log.variant);
    println!(
        "{}",
        table(
            &["id", "problem", "t_ref ms", "t_best ms", "speedup*", "fp16 SOL ms", "tokens"],
            &rows
        )
    );
    println!("* integrity-filtered");
    println!(
        "geomean {:.2}x  median {:.2}x  total ${:.2}",
        metrics::geomean_speedup(&speedups),
        metrics::median_speedup(&speedups),
        log.dollar_cost()
    );
}

fn cmd_run(
    _pos: &[String],
    opts: &HashMap<String, String>,
    seed: u64,
    jobs: usize,
    oracle: Option<Box<DynEvaluator>>,
) -> Result<(), String> {
    let spec = spec_from_opts(opts)?;
    let mut bench = Bench::new();
    if let Some(o) = oracle {
        bench.set_oracle(o);
    }
    let selected: Vec<usize> = match opts.get("problems") {
        Some(list) => list
            .split(',')
            .map(|id| {
                kernelbench::find(&bench.problems, id).ok_or(format!("unknown problem {id}"))
            })
            .collect::<Result<_, _>>()?,
        None => (0..bench.problems.len()).collect(),
    };
    let log = exec::run_variant_jobs(&bench, &spec, seed, None, jobs);
    print_log(&bench, &log, seed, &selected);
    Ok(())
}

fn cmd_shard(opts: &HashMap<String, String>, seed: u64) -> Result<(), String> {
    let index: usize = opt_require(opts, "index", "repro shard --index I --of N ...")?;
    let of: usize = opt_require(opts, "of", "repro shard --index I --of N ...")?;
    if of == 0 || index >= of {
        return Err(format!("shard: --index must be in 0..{of}"));
    }
    let spec = spec_from_opts(opts)?;
    let bench = Bench::new();
    // Sequentially-coupled variants (orchestrated cross-memory chain) are
    // one whole-variant task: the shard that owns it runs everything,
    // exactly as in the in-process parallel engine (ADR-002).
    let work = SuiteWork::single(spec, None, seed, bench.problems.len());
    let shard = suite_shard(&bench, &work, index, of);
    let out = opts
        .get("out")
        .cloned()
        .unwrap_or_else(|| format!("shard_{index}_of_{of}.json"));
    std::fs::write(&out, shard.to_json().to_string()).map_err(|e| e.to_string())?;
    println!(
        "shard {index}/{of}: {} of {} task(s) of `{}` (seed {seed}) -> {out}",
        shard.results.len(),
        exec::suite_tasks(&work.work, work.problems).len(),
        spec.label(),
    );
    println!("merge with: repro merge <all {of} shard files>");
    Ok(())
}

fn cmd_merge(pos: &[String], opts: &HashMap<String, String>) -> Result<(), String> {
    let files = &pos[1..];
    if files.is_empty() {
        return Err("usage: repro merge <shard.json>... [--out FILE]".into());
    }
    let shards: Vec<SuiteShard> = files
        .iter()
        .map(|f| {
            let text = std::fs::read_to_string(f).map_err(|e| format!("{f}: {e}"))?;
            SuiteShard::parse(&text).map_err(|e| format!("{f}: {e}"))
        })
        .collect::<Result<_, _>>()?;
    let seed = shards[0].work.seed;
    let logs = suite_merge(&shards)?;
    let bench = Bench::new();
    if shards[0].work.problems != bench.problems.len() {
        return Err(format!(
            "suite size mismatch: shards were produced against {} problems, this binary's \
             suite has {} — merge with a binary from the same build",
            shards[0].work.problems,
            bench.problems.len()
        ));
    }
    let all: Vec<usize> = (0..bench.problems.len()).collect();
    for log in &logs {
        print_log(&bench, log, seed, &all);
    }
    println!(
        "merged {} shard file(s) into {} run log(s); output is bit-identical to a \
         single-process run of the same job (seed {seed})",
        shards.len(),
        logs.len()
    );
    if let Some(out) = opts.get("out") {
        let json =
            ucutlass_repro::util::json::Json::Arr(logs.iter().map(|l| l.to_json()).collect());
        std::fs::write(out, json.to_string()).map_err(|e| e.to_string())?;
        println!("(merged logs written to {out})");
    }
    Ok(())
}

/// Open the ADR-010 run journal named by `--journal PATH [--resume]`.
/// No flag -> no journal. Without `--resume` a fresh journal is started
/// (truncating any existing file); with it the valid prefix of the
/// existing journal is recovered — a torn tail (crash mid-append) is
/// reported and dropped, while corruption inside the committed prefix
/// stays an in-band error.
fn journal_from_opts(opts: &HashMap<String, String>) -> Result<Option<RunJournal>, String> {
    let path = match opts.get("journal") {
        None => return Ok(None),
        Some(p) if p == "true" => {
            return Err("--journal needs a file path (--journal PATH [--resume])".into())
        }
        Some(p) => p,
    };
    if opts.contains_key("resume") {
        let j = RunJournal::resume(path)?;
        if j.torn_bytes() > 0 {
            println!(
                "journal {path}: dropped {} torn tail byte(s) (crash mid-append)",
                j.torn_bytes()
            );
        }
        Ok(Some(j))
    } else {
        Ok(Some(RunJournal::create(path)?))
    }
}

/// The job identity a sweep/schedule journal is bound to: seed plus the
/// exact variant set the command will run. A resume recomputes it and
/// [`RunJournal::bind`] refuses a mismatch in-band, so a journal can
/// never replay into a different spec, seed, or variant set. (`repro
/// serve` hashes its full `SuiteWork` instead, inside the coordinator.)
fn journal_job(scope: &str, seed: u64, detail: &str) -> String {
    format!("{:016x}", fnv64(format!("{scope} seed={seed:x} {detail}").as_bytes()))
}

/// `repro serve` (ADR-007): run a suite evaluation across a fleet of
/// `repro worker` subprocesses with deadlines, bounded retries, straggler
/// re-issue, and quarantine. The merged output is field-for-field what a
/// single-process `repro run` of the same spec and seed produces.
fn cmd_serve(opts: &HashMap<String, String>, seed: u64) -> Result<(), String> {
    const USAGE: &str = "repro serve --workers N [--deadline-ms D] [--retries R] \
                         [--quarantine-after K] [--shards S] [--eps PCT] [--tier T] [--dsl] \
                         [--sol orch|prompt] [--faults SLOT=ORD:FAULT,..;..] [--events FILE] \
                         [--cache PATH [--offline]] [--journal PATH [--resume]] [--out FILE]";
    let workers: usize = opt_parse(opts, "workers", 2)?;
    if workers == 0 {
        return Err(format!("--workers must be >= 1 ({USAGE})"));
    }
    let cfg = FleetConfig {
        workers,
        deadline: std::time::Duration::from_millis(opt_parse(opts, "deadline-ms", 30_000u64)?),
        retries: opt_parse(opts, "retries", 3)?,
        quarantine_after: opt_parse(opts, "quarantine-after", 3)?,
        shards: opt_parse(opts, "shards", 0)?,
        admission: Policy { epsilon: opt_parse::<f64>(opts, "eps", 100.0)? / 100.0, window: 0 },
        ..FleetConfig::default()
    };
    // validate the fault spec up front (slot range, fault names), then
    // hand workers the normalized per-slot form
    let fault_specs: Vec<String> =
        FaultPlan::parse_fleet(opts.get("faults").map(String::as_str).unwrap_or(""), workers)?
            .iter()
            .map(|p| p.spec())
            .collect();
    let events = match opts.get("events") {
        None => EventLog::new(),
        Some(p) if p == "true" => return Err(format!("--events needs a file path ({USAGE})")),
        Some(p) => {
            let f = std::fs::File::create(p).map_err(|e| format!("--events {p}: {e}"))?;
            EventLog::with_sink(Box::new(f))
        }
    };
    let spec = spec_from_opts(opts)?;
    let mut bench = Bench::new();
    // `--cache PATH [--offline]` (ADR-008): install the store on the
    // coordinator's bench (admission-order evals and any in-process
    // fallback go through it) and forward the same flags to every worker
    // so no fleet node re-measures a landed key. Fleets never write the
    // store — single-writer discipline: recording runs produce stores
    // (`repro run --cache`), fleets consume them read-through/offline.
    let mut worker_args: Vec<String> = Vec::new();
    let cache_monitor = match opts.get("cache") {
        None => None,
        Some(p) if p == "true" => return Err(format!("--cache needs a file path ({USAGE})")),
        Some(path) => {
            let offline = opts.contains_key("offline");
            let mode = if offline {
                CacheSessionMode::Offline
            } else {
                CacheSessionMode::ReadThrough
            };
            // fail fast, coordinator-side, before any worker spawns
            let (oracle, monitor) = cache_session(mode, path.into())?;
            bench.set_oracle(oracle);
            worker_args.extend(["--cache".to_string(), path.clone()]);
            if offline {
                worker_args.push("--offline".to_string());
            }
            Some(monitor)
        }
    };
    // `--journal PATH [--resume]` (ADR-010): every landed shard is
    // journaled (fsynced) before it is merged, so a killed coordinator
    // resumes with byte-identical output and zero re-measured landed
    // keys. While the run is live, a lease file next to the journal is
    // heartbeat every deadline/4; workers get `--lease`/`--lease-ms` so
    // any orphaned by a coordinator crash self-terminate within one
    // deadline instead of spinning forever.
    let journal = journal_from_opts(opts)?;
    let _lease = match (&journal, opts.get("journal")) {
        (Some(_), Some(jpath)) => {
            let lease_path = format!("{jpath}.lease");
            let interval = (cfg.deadline / 4).clamp(
                std::time::Duration::from_millis(10),
                std::time::Duration::from_secs(1),
            );
            worker_args.extend([
                "--lease".to_string(),
                lease_path.clone(),
                "--lease-ms".to_string(),
                cfg.deadline.as_millis().to_string(),
            ]);
            Some(LeaseKeeper::start(&lease_path, 0, interval)?)
        }
        _ => None,
    };
    let work = SuiteWork::single(spec, None, seed, bench.problems.len());
    let exe = std::env::current_exe().map_err(|e| format!("current_exe: {e}"))?;
    let outcome = run_fleet_journaled(
        &bench,
        &work,
        &cfg,
        subprocess_worker_factory(exe, fault_specs, worker_args),
        &events,
        journal.as_ref(),
    )
    .map_err(|e| e.to_string())?;
    events.flush();
    let all: Vec<usize> = (0..bench.problems.len()).collect();
    for log in &outcome.logs {
        print_log(&bench, log, seed, &all);
    }
    let st = outcome.stats;
    println!(
        "fleet: {} workers, {} shards merged ({} recovered from journal, {} assigns, \
         {} retries, {} timeouts, {} duplicates discarded, {} respawns, {} quarantined); \
         output is field-for-field a single-process run of the same job (seed {seed})",
        workers, st.shards, st.recovered, st.assigns, st.retries, st.timeouts, st.duplicates,
        st.respawns, st.quarantines
    );
    // coordinator-side cache verdict before --out is persisted (worker
    // processes keep their own counters; an offline worker that misses
    // exits nonzero on its own)
    if let Some(m) = &cache_monitor {
        println!("{}", m.summary());
        m.check()?;
    }
    if let Some(out) = opts.get("out") {
        let json = ucutlass_repro::util::json::Json::Arr(
            outcome.logs.iter().map(|l| l.to_json()).collect(),
        );
        std::fs::write(out, json.to_string()).map_err(|e| e.to_string())?;
        println!("(merged logs written to {out})");
    }
    Ok(())
}

/// `repro worker`: one fleet worker speaking the ADR-007 line protocol on
/// stdin/stdout. Spawned by `repro serve`; not meant to be run by hand.
/// `--faults` scripts this worker's misbehavior for the fault-injection
/// harness; `--fault-offset` is where a respawned worker resumes the plan.
fn cmd_worker(opts: &HashMap<String, String>) -> Result<(), String> {
    let faults = FaultPlan::parse(opts.get("faults").map(String::as_str).unwrap_or(""))?;
    let start_ordinal: u64 = opt_parse(opts, "fault-offset", 0)?;
    let mut bench = Bench::new();
    // `--cache PATH [--offline]` forwarded by `repro serve` (ADR-008):
    // serve landed keys from the shared store instead of re-measuring.
    // Workers never write the store (single-writer discipline); stdout is
    // the wire protocol, so the verdict goes to stderr via the Err path.
    let cache_monitor = match opts.get("cache") {
        None => None,
        Some(p) if p == "true" => return Err("worker --cache needs a file path".into()),
        Some(path) => {
            let mode = if opts.contains_key("offline") {
                CacheSessionMode::Offline
            } else {
                CacheSessionMode::ReadThrough
            };
            let (oracle, monitor) = cache_session(mode, path.into())?;
            bench.set_oracle(oracle);
            Some(monitor)
        }
    };
    // `--lease PATH --lease-ms N` forwarded by a journaled `repro serve`
    // (ADR-010): watch the coordinator's heartbeat and exit once it goes
    // stale. The worker loop checks between requests; a detached watchdog
    // covers the cases that check can't reach (blocked reading stdin from
    // a dead-but-unreaped coordinator, compute-bound mid-shard, scripted
    // hang faults) by polling every timeout/4 and exiting the process.
    let lease = match opts.get("lease") {
        None => None,
        Some(p) if p == "true" => return Err("worker --lease needs a file path".into()),
        Some(p) => {
            let ms: u64 = opt_parse(opts, "lease-ms", 30_000u64)?;
            let timeout = std::time::Duration::from_millis(ms.max(1));
            let mut watchdog = LeaseMonitor::new(p, timeout);
            let poll = (timeout / 4).clamp(
                std::time::Duration::from_millis(10),
                std::time::Duration::from_millis(500),
            );
            std::thread::Builder::new()
                .name("lease-watchdog".into())
                .spawn(move || loop {
                    std::thread::sleep(poll);
                    if watchdog.stale() {
                        eprintln!("worker: coordinator lease stale; exiting");
                        // exit 0: orphan hygiene, not a worker fault
                        std::process::exit(0);
                    }
                })
                .map_err(|e| format!("worker: spawn lease watchdog: {e}"))?;
            Some(LeaseMonitor::new(p, timeout))
        }
    };
    let wopts = WorkerOpts { faults, start_ordinal, lease };
    let kill = std::sync::atomic::AtomicBool::new(false);
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    worker_loop(&bench, stdin.lock(), stdout.lock(), &wopts, &kill)?;
    // an offline worker that had to answer misses in-band must not exit
    // clean — the cache did not cover its shards
    match &cache_monitor {
        Some(m) => m.check(),
        None => Ok(()),
    }
}

fn cmd_validate(opts: &HashMap<String, String>, seed: u64) -> Result<(), String> {
    let dir = opts.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
    let mut rt = runtime::Runtime::open(&dir).map_err(|e| e.to_string())?;
    let problems: Vec<String> = match opts.get("problem") {
        Some(p) => vec![p.clone()],
        None => rt.manifest.problems.keys().cloned().collect(),
    };
    let mut rows = Vec::new();
    let mut failures = 0;
    for pname in &problems {
        let variants: Vec<String> = rt
            .manifest
            .problems
            .get(pname)
            .ok_or(format!("unknown problem {pname}"))?
            .variants
            .keys()
            .cloned()
            .collect();
        for v in variants {
            let rep = rt.validate_variant(pname, &v, seed).map_err(|e| e.to_string())?;
            if !rep.pass {
                failures += 1;
            }
            rows.push(vec![
                pname.clone(),
                v,
                format!("{:.2e}", rep.max_abs_err),
                format!("{}", rep.elems),
                if rep.pass { "PASS".into() } else { "FAIL".into() },
            ]);
        }
    }
    println!("{}", table(&["problem", "variant", "max |err|", "elems", "status"], &rows));
    if failures > 0 {
        return Err(format!("{failures} variant(s) failed numeric validation"));
    }
    println!("all {} validations passed (PJRT CPU, seeded inputs)", rows.len());
    Ok(())
}

fn cmd_schedule(
    opts: &HashMap<String, String>,
    seed: u64,
    jobs: usize,
    oracle: Option<Box<DynEvaluator>>,
) -> Result<(), String> {
    let tier = tier_of(opts.get("tier").map(String::as_str).unwrap_or("max"))?;
    let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, tier);
    let mut bench = Bench::new();
    if let Some(o) = oracle {
        bench.set_oracle(o);
    }
    let env = bench.env();
    let pipeline = IntegrityPipeline::default();
    let policy = Policy {
        epsilon: opt_parse::<f64>(opts, "eps", 100.0)? / 100.0,
        window: opt_parse(opts, "window", 0)?,
    };

    // Single-pass sweep engine (ADR-005): sessions are driven ONCE to
    // exhaustion; the policy's realized outcome (stop indices, tokens,
    // truncated log) is derived offline through the shared StopRule —
    // provably equal to running the policy online (scheduler determinism
    // tests + the sweep golden test), at one session pass instead of two
    // (and one instead of 72 when sweeping the grid).
    //
    // With `--journal` (ADR-010) that one exhausted pass — the only
    // evaluator-touching step — is journaled before any policy is
    // applied, so a killed run resumes from the record with zero
    // evaluator calls and every outcome below recomputed identically.
    let journal = journal_from_opts(opts)?;
    if let Some(j) = &journal {
        let job = journal_job("schedule", seed, &format!("variant={}", spec.label()));
        j.bind("schedule", &job, 0)?;
    }
    let run = match &journal {
        None => scheduler::sweep_sessions(&env, &spec, seed, jobs, &pipeline, seed),
        Some(j) => {
            let (log, recovered) = j.variant_log(&spec.label(), || {
                scheduler::sweep_sessions(&env, &spec, seed, jobs, &pipeline, seed).log
            })?;
            if recovered {
                println!(
                    "journal: recovered exhausted pass for {} (0 evaluator calls)",
                    spec.label()
                );
            }
            let sweep = scheduler::PolicySweep::over(&log, &pipeline, seed);
            scheduler::SweepRun { spec, log, sweep }
        }
    };
    let online = run.outcome(&policy);
    let fixed = run.outcome(&Policy::fixed());
    if let Some(j) = &journal {
        // journal the stop decision before acting on (printing) it; on
        // resume the re-derived decision is cross-checked against the
        // record, so journal/build disagreement is an in-band error
        // rather than silently divergent output
        j.record_stop(
            &spec.label(),
            &policy.label(),
            online.attempts_total() as u64,
            online.tokens_used,
        )?;
    }
    // The engine runs orchestrated sessions with per-problem memory
    // (round-robin has no defined cross-problem order, ADR-002), so these
    // numbers are not comparable to `repro exp` figures, which thread
    // MANTIS memory across problems sequentially.
    println!("note: orchestrated sessions use per-problem memory (no cross-problem chain)");
    let geo = |log: &ucutlass_repro::agent::RunLog| pipeline.filtered_geomean(log, seed);
    println!("variant: {}   policy: {}", spec.label(), policy.label());
    println!(
        "online:  {} of {} attempts ({:.0}% saved, {} problems stopped early)",
        online.attempts_total(),
        fixed.attempts_total(),
        online.attempt_savings() * 100.0,
        online.stopped_early()
    );
    println!(
        "tokens:  {} vs fixed {}  -> {:.0}% saved",
        online.tokens_used,
        fixed.tokens_used,
        online.token_savings() * 100.0
    );
    println!(
        "geomean: online {:.2}x vs fixed {:.2}x ({:.0}% retention)",
        geo(&online.log),
        geo(&run.log),
        metrics::retention(geo(&online.log), geo(&run.log)) * 100.0
    );
    println!(
        "single pass: outcomes derived offline from one exhausted session run \
         (online agreement is test-pinned; `repro sweep` grids 72 policies at the \
         same cost)"
    );
    if let Some(j) = &journal {
        j.record_done()?;
    }
    Ok(())
}

/// `repro sweep` (ADR-005): replay the full 72-policy fig8/fig9 grid for
/// every Pareto-study variant from ONE exhausted session pass per variant.
/// With `--trace PATH` the pass is served strictly from a recorded trace
/// (`--live` falls through and extends); `--out FILE` writes the grid as
/// machine-readable JSON.
fn cmd_sweep(
    opts: &HashMap<String, String>,
    seed: u64,
    jobs: usize,
    oracle: Option<(Box<DynEvaluator>, OracleMonitor)>,
) -> Result<(), String> {
    let mut bench = Bench::new();
    // `repro sweep --trace PATH` is sugar for `repro replay sweep`; when
    // invoked through record/replay the wrapper hands its monitor in (and
    // prints the summary itself afterwards).
    let (monitor, wrapped) = match (oracle, opts.get("trace")) {
        (Some((o, m)), _) => {
            bench.set_oracle(o);
            (Some(m), true)
        }
        (None, Some(path)) => {
            if path == "true" {
                return Err("--trace needs a file path (repro sweep --trace PATH)".into());
            }
            let mode = if opts.contains_key("live") {
                TraceMode::ReplayExtend
            } else {
                TraceMode::ReplayStrict
            };
            let (o, m) = trace_session(mode, path)?;
            bench.set_oracle(o);
            (Some(OracleMonitor::Trace(m)), false)
        }
        (None, None) => {
            if opts.contains_key("live") {
                return Err("--live needs --trace PATH (repro sweep --trace PATH --live)".into());
            }
            (None, false)
        }
    };
    let variants: Vec<VariantSpec> = match opts.get("tier") {
        Some(t) => {
            let tier = tier_of(t)?;
            figures::pareto_variants().into_iter().filter(|s| s.tier == tier).collect()
        }
        None => figures::pareto_variants(),
    };
    let pipeline = IntegrityPipeline::default();
    // `--journal PATH [--resume]` (ADR-010): each variant's exhausted
    // session pass — the only evaluator-touching step — is journaled
    // before any policy is applied to it, so a killed sweep resumes
    // paying only for the variants it had not yet finished.
    let journal = journal_from_opts(opts)?;
    if let Some(j) = &journal {
        let labels: Vec<String> = variants.iter().map(|s| s.label()).collect();
        let job = journal_job("sweep", seed, &format!("variants={}", labels.join("|")));
        j.bind("sweep", &job, 0)?;
    }
    let mut out_json = ucutlass_repro::util::json::Json::Arr(Vec::new());
    for spec in &variants {
        let env = bench.env();
        let run = match &journal {
            None => scheduler::sweep_sessions(&env, spec, seed, jobs, &pipeline, seed),
            Some(j) => {
                let (log, recovered) = j.variant_log(&spec.label(), || {
                    scheduler::sweep_sessions(&env, spec, seed, jobs, &pipeline, seed).log
                })?;
                if recovered {
                    println!(
                        "journal: recovered exhausted pass for {} (0 evaluator calls)",
                        spec.label()
                    );
                }
                let sweep = scheduler::PolicySweep::over(&log, &pipeline, seed);
                scheduler::SweepRun { spec: *spec, log, sweep }
            }
        };
        println!(
            "== sweep: {} == (1 exhausted session pass, {} policies offline)",
            spec.label(),
            run.sweep.results.len()
        );
        println!(
            "fixed: geomean {:.2}x, {} tokens",
            run.sweep.fixed.geomean_fixed, run.sweep.fixed.tokens_fixed
        );
        let mut rows = Vec::new();
        for r in &run.sweep.results {
            rows.push(vec![
                r.policy.label(),
                format!("{}", r.attempts_used.iter().sum::<usize>()),
                format!("{:.0}%", r.token_savings() * 100.0),
                format!("{:.2}x", r.geomean),
                format!("{:.0}%", r.geomean_retention() * 100.0),
            ]);
        }
        println!(
            "{}",
            table(&["policy", "attempts", "token savings", "geomean", "geo retention"], &rows)
        );
        if let (Some(j), Some(best)) = (&journal, run.sweep.best(0.95)) {
            // the per-variant stop decision (the winning policy under the
            // fig9 retention floor), journaled before it is reported
            j.record_stop(
                &spec.label(),
                &best.policy.label(),
                best.attempts_used.iter().sum::<usize>() as u64,
                best.tokens_used,
            )?;
        }
        match run.sweep.best(0.95) {
            Some(best) => println!(
                "best (≥95% retention): {} -> {:.0}% token savings, {:.2}x efficiency gain",
                best.policy.label(),
                best.token_savings() * 100.0,
                best.efficiency_gain()
            ),
            None => println!("best (≥95% retention): none met the constraint"),
        }
        if let ucutlass_repro::util::json::Json::Arr(items) = &mut out_json {
            let mut v = ucutlass_repro::util::json::Json::obj();
            let mut fixed = ucutlass_repro::util::json::Json::obj();
            fixed
                .set("geomean", run.sweep.fixed.geomean_fixed)
                .set("tokens", run.sweep.fixed.tokens_fixed);
            let policies: Vec<ucutlass_repro::util::json::Json> = run
                .sweep
                .results
                .iter()
                .map(|r| {
                    let mut p = ucutlass_repro::util::json::Json::obj();
                    p.set("eps", r.policy.epsilon)
                        .set("window", r.policy.window as u64)
                        .set("attempts", r.attempts_used.iter().sum::<usize>())
                        .set("tokens", r.tokens_used)
                        .set("geomean", r.geomean)
                        .set("token_savings", r.token_savings())
                        .set("geo_retention", r.geomean_retention());
                    p
                })
                .collect();
            v.set("variant", spec.label())
                .set("seed", format!("{seed:x}"))
                .set("fixed", fixed)
                .set("policies", ucutlass_repro::util::json::Json::Arr(policies));
            items.push(v);
        }
    }
    // Trace problems must fail BEFORE the machine-readable grid is
    // persisted: a strict miss answers in-band with 0.0 values, so a
    // miss-poisoned sweep.json must never reach disk for a consumer to
    // read.
    if let Some(m) = &monitor {
        if !wrapped {
            println!("{}", m.summary());
        }
        m.check()?;
    }
    // done only after the oracle verdict: a miss-poisoned sweep must not
    // be journaled as complete any more than it may persist --out
    if let Some(j) = &journal {
        j.record_done()?;
    }
    if let Some(out) = opts.get("out") {
        std::fs::write(out, out_json.to_string()).map_err(|e| e.to_string())?;
        println!("(sweep grid written to {out})");
    }
    Ok(())
}

fn cmd_list() -> Result<(), String> {
    let problems = kernelbench::suite();
    let gpu = sol::H100_SXM;
    let rows: Vec<Vec<String>> = problems
        .iter()
        .map(|p| {
            let a = sol::analyze(p, &gpu);
            vec![
                p.id.to_string(),
                p.name.into(),
                format!("{:.3e}", p.flops() as f64),
                format!("{:.1}", p.arithmetic_intensity()),
                format!("{:?}", a.bottleneck),
                format!("{:.3}", a.t_sol_ms),
                p.artifact.unwrap_or("-").into(),
            ]
        })
        .collect();
    println!(
        "{}",
        table(
            &["id", "name", "FLOPs", "AI", "bottleneck", "t_SOL ms", "AOT artifact"],
            &rows
        )
    );
    Ok(())
}
