//! # ucutlass-repro
//!
//! Reproduction of *"Improving Efficiency of GPU Kernel Optimization Agents
//! using a Domain-Specific Language and Speed-of-Light Guidance"* (NVIDIA,
//! CS.LG 2026) as a three-layer Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) is the paper's system contribution:
//!
//! * [`dsl`] — the µCUTLASS DSL: lexer, parser, typed configuration IR,
//!   table-driven constraint validation (per-arch `ConstraintTable` rows
//!   covering the SM70–SM100 rule set from the paper's Appendix A.1
//!   grammar), the pre-resolved [`dsl::plan::KernelPlan`] lowering
//!   artifact every consumer layer reads (ADR-001), a config-hash-keyed
//!   plan cache for the agent hot loop, and code generation.
//! * [`sol`] — Speed-of-Light analysis: roofline bounds, clock-aware peaks,
//!   FP16 augmentation, and report generation (paper §4.1, Appendix A.2).
//! * [`perfmodel`] — the calibrated H100 analytical performance model that
//!   substitutes for the paper's GPU testbed (DESIGN.md §2).
//! * [`kernelbench`] — the 59-problem KernelBench subset (Appendix A.3).
//! * [`agent`] — SimLLM policy models (three capability tiers) and the
//!   MI / in-prompt controllers (paper §5.5).
//! * [`mantis`] — the orchestrated Measure–Analyze–Nominate–Triage–
//!   Implement–Summarize controller with gap-aware ROI triage (paper §4.2).
//! * [`scheduler`] — SOL-guided budget scheduling: ε/w eligibility rules,
//!   an online breadth-first round-robin engine that applies them *during*
//!   execution, offline replay that provably agrees with it, the
//!   single-pass multi-policy sweep engine behind `repro sweep` (all 72
//!   fig8/fig9 policies from one exhausted session pass, ADR-005), Pareto
//!   frontiers, efficiency gain (paper §4.3, §6.2).
//! * [`exec`] — deterministic parallel execution: a std-only work-stealing
//!   pool fanning independent (variant, problem, seed) tasks across cores
//!   with bit-identical output to the serial path (ADR-002).
//! * [`eval`] — the unified evaluation backend API (ADR-003): the
//!   `Evaluator` trait with batched `eval_batch`, serializable
//!   `EvalRequest`/`EvalResponse`, analytic / PJRT / manifest backends,
//!   the shard/merge protocol behind `repro shard` + `repro merge`, and
//!   the recorded-trace backend (ADR-004) behind `repro record` +
//!   `repro replay` — persist a real run's measurements once, re-run
//!   every scheduler/policy experiment offline from the trace. Serving
//!   stores index by the allocation-free interned `EvalKey` (ADR-005);
//!   string keys survive only in JSON and diagnostics.
//! * [`store`] — the persistent content-addressed eval store (ADR-008):
//!   binary trace format v1 (append-only length-prefixed records, magic +
//!   version header, key→offset index footer — a million-measurement
//!   store opens and serves without parsing JSON) and the write-through
//!   `CachedEvaluator` behind `repro … --cache PATH`, layering memory →
//!   store → live backend so no measurement is ever paid for twice
//!   across runs, users, or fleet nodes; `repro cache
//!   stats|export|import|compact` bridges losslessly to JSONL v2.
//! * [`journal`] — crash-safe runs (ADR-010): the durable WAL-style run
//!   journal behind `repro serve|sweep|schedule --journal PATH
//!   [--resume]` (every landed shard / exhausted variant pass / stop
//!   decision is journaled before it is acted on; `kill -9` at any
//!   point resumes to byte-identical output with zero re-measured
//!   work), the coordinator lease that lets orphaned workers
//!   self-terminate, and the store repair/GC maintenance path.
//! * [`fleet`] — the fault-tolerant fleet coordinator behind `repro serve`
//!   (ADR-007): N `repro worker` subprocesses driven over a version-gated
//!   line protocol with deadlines, bounded retries, straggler re-issue,
//!   quarantine, SOL-aware admission ordering, a deterministic
//!   fault-injection harness, and incremental merge whose output is
//!   field-for-field identical to single-process `exec::eval_variants`.
//! * [`analyze`] — the static analysis engine over lowered µCUTLASS
//!   programs (ADR-009): a multi-rule lint pass emitting structured
//!   diagnostics (stable `A1xx/A2xx/A3xx/C4xx` codes, severity, span,
//!   *why* text, machine-applicable fix-its) behind `repro lint`, plus
//!   the hot-loop `PruneGate` that skips SOL-infeasible and duplicate
//!   candidates before they reach the evaluator — deterministically,
//!   recorded in RunLogs so ADR-004 replay agrees bit-for-bit.
//! * [`integrity`] — SOL-ceiling, LLM-game-detector and PyTorch-only
//!   detectors with the full label taxonomy (paper §4.4, §6.3).
//! * [`metrics`] — Fast-p / Attempt-Fast-p curves, signed area, retention.
//! * [`runtime`] — PJRT executor: loads the AOT HLO artifacts produced by
//!   `python/compile/aot.py` and numerically validates candidate kernels.
//! * [`experiments`] — one driver per paper figure/table (fig3…fig14, tab4).
//!
//! Python never runs on the request path: `make artifacts` lowers the L2/L1
//! JAX+Pallas graphs to HLO text once; everything here is self-contained.

pub mod util;
pub mod dsl;
pub mod analyze;
pub mod sol;
pub mod kernelbench;
pub mod perfmodel;
pub mod agent;
pub mod mantis;
pub mod scheduler;
pub mod exec;
pub mod eval;
pub mod store;
pub mod journal;
pub mod fleet;
pub mod integrity;
pub mod metrics;
pub mod runtime;
pub mod experiments;
pub mod report;
