//! The typed run journal: what `repro serve` / `sweep` / `schedule`
//! write through, and what `--resume` replays (ADR-010).
//!
//! Record kinds (one JSON object per WAL frame, discriminated by
//! `"kind"`):
//!
//! * `start` — run identity: `scope` (`serve`/`sweep`/`schedule`), the
//!   `job` hash, and the shard count `of` (0 for session scopes). A
//!   resume validates identity before touching anything else, so a
//!   journal can never be replayed into a different run.
//! * `coordinator` — one per coordinator incarnation, carrying its
//!   fencing `token` (0 for the first, predecessor max + 1 after). All
//!   later records are tagged with the incarnation that wrote them, so
//!   a resumed coordinator can attribute — and never double-charge —
//!   work a predecessor left in flight: landed shards are replayed
//!   into `SuiteMerge` (never re-assigned, never re-measured), while
//!   in-flight assignments that never landed simply re-run under the
//!   new token with fresh failure accounting.
//! * `shard` — a landed suite shard, journaled *before* it is merged.
//! * `variant` — one exhausted session pass (`RunLog`) for a sweep /
//!   schedule variant, journaled before any policy is applied to it
//!   (ADR-005: the whole 72-policy grid is derivable offline from this
//!   one record, so resume re-runs nothing).
//! * `stop` — a scheduler stop decision (variant, policy, attempts,
//!   tokens), journaled before it is printed or written to `--out`; on
//!   resume it is cross-checked against the re-derived decision.
//! * `done` — the run completed; a resume of a done journal reassembles
//!   output without spawning any work at all.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use crate::agent::RunLog;
use crate::eval::manifest::SuiteShard;
use crate::util::json::Json;

use super::format::{scan_journal, JournalWriter, Tail};

/// A recovered `stop` record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StopRecord {
    pub label: String,
    pub policy: String,
    pub attempts: u64,
    pub tokens: u64,
}

struct State {
    writer: JournalWriter,
    token: u64,
    bound: bool,
    done: bool,
    torn_bytes: u64,
    // recovered state (empty for a fresh journal)
    start: Option<(String, String, usize)>, // (scope, job, of)
    max_token: u64,
    shards: Vec<SuiteShard>,
    variants: BTreeMap<String, Json>,
    stops: Vec<StopRecord>,
}

/// A durable write-ahead journal for one run. Everything the run acts
/// on — a landed shard, an exhausted variant pass, a stop decision —
/// is appended (and fsynced) here first, so `kill -9` at any point
/// leaves a prefix that [`RunJournal::resume`] continues from with
/// byte-identical output and zero re-measured landed work.
pub struct RunJournal {
    state: Mutex<State>,
}

fn get_u64(j: &Json, k: &str, what: &str) -> Result<u64, String> {
    j.get(k).and_then(|v| v.as_u64()).ok_or_else(|| format!("journal: {what}: bad {k}"))
}

fn get_str<'a>(j: &'a Json, k: &str, what: &str) -> Result<&'a str, String> {
    j.get(k).and_then(|v| v.as_str()).ok_or_else(|| format!("journal: {what}: bad {k}"))
}

impl RunJournal {
    /// Start a fresh journal at `path` (truncating any existing file —
    /// pass `--resume` to continue one instead).
    pub fn create(path: impl AsRef<Path>) -> Result<RunJournal, String> {
        let writer = JournalWriter::create(path)?;
        Ok(RunJournal {
            state: Mutex::new(State {
                writer,
                token: 0,
                bound: false,
                done: false,
                torn_bytes: 0,
                start: None,
                max_token: 0,
                shards: Vec::new(),
                variants: BTreeMap::new(),
                stops: Vec::new(),
            }),
        })
    }

    /// Recover the valid prefix of an existing journal. Corruption in
    /// the committed prefix is an in-band error; a torn tail (crash
    /// mid-append) is truncated away. The identity check against the
    /// resuming run happens at [`RunJournal::bind`].
    pub fn resume(path: impl AsRef<Path>) -> Result<RunJournal, String> {
        let path = path.as_ref();
        let scan = scan_journal(path)?;
        let torn_bytes = match scan.tail {
            Tail::Clean => 0,
            Tail::Torn { dropped } => dropped,
        };
        let mut start: Option<(String, String, usize)> = None;
        let mut max_token = 0u64;
        let mut done = false;
        let mut shards: Vec<SuiteShard> = Vec::new();
        let mut shard_raw: BTreeMap<usize, String> = BTreeMap::new();
        let mut variants: BTreeMap<String, Json> = BTreeMap::new();
        let mut stops: Vec<StopRecord> = Vec::new();
        for (n, r) in scan.records.iter().enumerate() {
            let what = format!("record {n}");
            match get_str(r, "kind", &what)? {
                "start" => {
                    if start.is_some() {
                        return Err(format!("journal: {what}: duplicate start record"));
                    }
                    start = Some((
                        get_str(r, "scope", &what)?.to_string(),
                        get_str(r, "job", &what)?.to_string(),
                        get_u64(r, "of", &what)? as usize,
                    ));
                }
                "coordinator" => max_token = max_token.max(get_u64(r, "token", &what)?),
                "shard" => {
                    let index = get_u64(r, "index", &what)? as usize;
                    let sj = r.get("shard").ok_or_else(|| format!("journal: {what}: missing shard"))?;
                    let shard = SuiteShard::from_json(sj)
                        .map_err(|e| format!("journal: {what}: {e}"))?;
                    if shard.index != index {
                        return Err(format!(
                            "journal: {what}: index {index} does not match shard {}",
                            shard.index
                        ));
                    }
                    let raw = sj.to_string();
                    match shard_raw.get(&index) {
                        // a duplicate identical record is a benign replay
                        // (e.g. a resumed coordinator raced its own crash);
                        // a *conflicting* one means two coordinators wrote
                        // this journal concurrently — refuse the lot
                        Some(prev) if *prev == raw => {}
                        Some(_) => {
                            return Err(format!(
                                "journal: {what}: conflicting records for shard {index} \
                                 (two coordinators wrote this journal?)"
                            ));
                        }
                        None => {
                            shard_raw.insert(index, raw);
                            shards.push(shard);
                        }
                    }
                }
                "variant" => {
                    let label = get_str(r, "label", &what)?.to_string();
                    let log =
                        r.get("log").ok_or_else(|| format!("journal: {what}: missing log"))?;
                    match variants.get(&label) {
                        Some(prev) if prev.to_string() == log.to_string() => {}
                        Some(_) => {
                            return Err(format!(
                                "journal: {what}: conflicting variant records for {label:?}"
                            ));
                        }
                        None => {
                            variants.insert(label, log.clone());
                        }
                    }
                }
                "stop" => stops.push(StopRecord {
                    label: get_str(r, "label", &what)?.to_string(),
                    policy: get_str(r, "policy", &what)?.to_string(),
                    attempts: get_u64(r, "attempts", &what)?,
                    tokens: get_u64(r, "tokens", &what)?,
                }),
                "done" => done = true,
                other => {
                    return Err(format!(
                        "journal: {what}: unknown record kind {other:?} \
                         (written by a newer build?)"
                    ));
                }
            }
        }
        if start.is_none() {
            return Err(format!(
                "journal {}: no start record (torn at creation); delete it and start fresh",
                path.display()
            ));
        }
        let writer = JournalWriter::append_to(path, scan.valid_end)?;
        Ok(RunJournal {
            state: Mutex::new(State {
                writer,
                token: 0,
                bound: false,
                done,
                torn_bytes,
                start,
                max_token,
                shards,
                variants,
                stops,
            }),
        })
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, State> {
        self.state.lock().expect("run journal lock")
    }

    fn append(state: &mut State, payload: &Json) -> Result<(), String> {
        state.writer.append(payload.to_string().as_bytes())
    }

    /// Bind the journal to this run's identity. A fresh journal writes
    /// its `start` record here; a resumed one validates that (scope,
    /// job, of) match what it recorded — refusing, in-band, to replay
    /// into a different run. Either way a new `coordinator` record is
    /// appended (token 0, or predecessor max + 1) and the recovered
    /// landed shards are handed back for replay into `SuiteMerge`.
    pub fn bind(&self, scope: &str, job: &str, of: usize) -> Result<Vec<SuiteShard>, String> {
        let mut s = self.lock();
        if s.bound {
            return Err("journal: bind called twice".into());
        }
        match s.start.clone() {
            None => {
                let mut o = Json::obj();
                o.set("kind", "start").set("scope", scope).set("job", job).set("of", of);
                Self::append(&mut s, &o)?;
                s.start = Some((scope.to_string(), job.to_string(), of));
                s.token = 0;
            }
            Some((jscope, jjob, jof)) => {
                if jscope != scope || jjob != job || jof != of {
                    return Err(format!(
                        "journal: belongs to a different run (journal: {jscope} job {jjob} \
                         of {jof}; this run: {scope} job {job} of {of}) — resume with the \
                         same spec, seed, and shard count"
                    ));
                }
                s.token = s.max_token + 1;
            }
        }
        let token = s.token;
        let mut o = Json::obj();
        o.set("kind", "coordinator").set("token", token);
        Self::append(&mut s, &o)?;
        s.max_token = s.max_token.max(token);
        s.bound = true;
        Ok(std::mem::take(&mut s.shards))
    }

    /// This incarnation's fencing token (valid after [`RunJournal::bind`]).
    pub fn token(&self) -> u64 {
        self.lock().token
    }

    /// Whether the journaled run already completed.
    pub fn done(&self) -> bool {
        self.lock().done
    }

    /// Bytes of torn tail discarded at resume (0 for a clean journal).
    pub fn torn_bytes(&self) -> u64 {
        self.lock().torn_bytes
    }

    /// Journal a landed shard. On `Ok(())` the record is durable —
    /// only then may the shard be merged.
    pub fn record_shard(&self, shard: &SuiteShard) -> Result<(), String> {
        let mut s = self.lock();
        let mut o = Json::obj();
        o.set("kind", "shard")
            .set("token", s.token)
            .set("index", shard.index)
            .set("shard", shard.to_json());
        Self::append(&mut s, &o)
    }

    /// Run-or-recover one exhausted variant pass: if the journal holds
    /// a `variant` record for `label`, decode and return it (`true` =
    /// recovered, zero evaluator calls); otherwise run `live` and
    /// journal its log before returning it.
    pub fn variant_log(
        &self,
        label: &str,
        live: impl FnOnce() -> RunLog,
    ) -> Result<(RunLog, bool), String> {
        let recovered = self.lock().variants.get(label).cloned();
        if let Some(j) = recovered {
            let mut plans = crate::dsl::PlanCache::new();
            let log = RunLog::from_json(&j, &mut plans)
                .map_err(|e| format!("journal: variant {label:?}: {e}"))?;
            return Ok((log, true));
        }
        let log = live();
        let mut s = self.lock();
        let mut o = Json::obj();
        o.set("kind", "variant")
            .set("token", s.token)
            .set("label", label)
            .set("log", log.to_json());
        Self::append(&mut s, &o)?;
        Ok((log, false))
    }

    /// Journal a scheduler stop decision before acting on it. On
    /// resume the decision must be *re-derivable*: if the journal
    /// already holds a stop for this (variant, policy) with different
    /// numbers, the journal and the build disagree and the mismatch is
    /// an in-band error rather than silently divergent output.
    pub fn record_stop(
        &self,
        label: &str,
        policy: &str,
        attempts: u64,
        tokens: u64,
    ) -> Result<(), String> {
        let mut s = self.lock();
        if let Some(prev) =
            s.stops.iter().find(|r| r.label == label && r.policy == policy)
        {
            if prev.attempts != attempts || prev.tokens != tokens {
                return Err(format!(
                    "journal: stop decision for {label:?} under {policy} diverged on resume \
                     (journaled {} attempts / {} tokens, re-derived {attempts} / {tokens})",
                    prev.attempts, prev.tokens
                ));
            }
            return Ok(()); // identical decision already journaled
        }
        let mut o = Json::obj();
        o.set("kind", "stop")
            .set("token", s.token)
            .set("label", label)
            .set("policy", policy)
            .set("attempts", attempts)
            .set("tokens", tokens);
        Self::append(&mut s, &o)?;
        s.stops.push(StopRecord {
            label: label.to_string(),
            policy: policy.to_string(),
            attempts,
            tokens,
        });
        Ok(())
    }

    /// Journal run completion. Idempotent across incarnations.
    pub fn record_done(&self) -> Result<(), String> {
        let mut s = self.lock();
        if s.done {
            return Ok(());
        }
        let mut o = Json::obj();
        o.set("kind", "done").set("token", s.token);
        Self::append(&mut s, &o)?;
        s.done = true;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ucutlass_jrun_{}_{name}", std::process::id()))
    }

    #[test]
    fn fresh_bind_then_resume_carries_tokens_and_done() {
        let p = tmp("bind.journal");
        let _ = std::fs::remove_file(&p);
        {
            let j = RunJournal::create(&p).unwrap();
            let shards = j.bind("serve", "cafe", 4).unwrap();
            assert!(shards.is_empty());
            assert_eq!(j.token(), 0);
            assert!(!j.done());
        }
        {
            let j = RunJournal::resume(&p).unwrap();
            assert!(!j.done());
            let shards = j.bind("serve", "cafe", 4).unwrap();
            assert!(shards.is_empty());
            assert_eq!(j.token(), 1, "resume fences with predecessor max + 1");
            j.record_done().unwrap();
        }
        {
            let j = RunJournal::resume(&p).unwrap();
            assert!(j.done(), "done survives");
            let _ = j.bind("serve", "cafe", 4).unwrap();
            assert_eq!(j.token(), 2);
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn bind_refuses_a_different_run_in_band() {
        let p = tmp("ident.journal");
        let _ = std::fs::remove_file(&p);
        {
            let j = RunJournal::create(&p).unwrap();
            j.bind("serve", "cafe", 4).unwrap();
        }
        for (scope, job, of) in
            [("sweep", "cafe", 4), ("serve", "beef", 4), ("serve", "cafe", 2)]
        {
            let j = RunJournal::resume(&p).unwrap();
            let err = j.bind(scope, job, of).unwrap_err();
            assert!(err.contains("different run"), "got: {err}");
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn resume_without_a_start_record_is_an_in_band_error() {
        let p = tmp("nostart.journal");
        let _ = std::fs::remove_file(&p);
        {
            let _ = RunJournal::create(&p).unwrap(); // header only, never bound
        }
        let err = RunJournal::resume(&p).unwrap_err();
        assert!(err.contains("no start record"), "got: {err}");
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn stop_decisions_cross_check_on_resume() {
        let p = tmp("stop.journal");
        let _ = std::fs::remove_file(&p);
        {
            let j = RunJournal::create(&p).unwrap();
            j.bind("schedule", "cafe", 0).unwrap();
            j.record_stop("v", "e=1 w=8", 100, 5000).unwrap();
        }
        {
            let j = RunJournal::resume(&p).unwrap();
            j.bind("schedule", "cafe", 0).unwrap();
            // same decision re-derived: fine (and not re-journaled)
            j.record_stop("v", "e=1 w=8", 100, 5000).unwrap();
            // a different policy is a new decision
            j.record_stop("v", "e=0.5 w=4", 90, 4500).unwrap();
            // a diverging re-derivation is an in-band error
            let err = j.record_stop("v", "e=1 w=8", 99, 5000).unwrap_err();
            assert!(err.contains("diverged"), "got: {err}");
        }
        let _ = std::fs::remove_file(&p);
    }
}
