//! WAL framing for the run journal (ADR-010) — the ADR-008 format
//! family applied to an append-only log with **no** index footer: a
//! journal must be readable after a crash at *any* byte, so all of its
//! structure lives in the records themselves.
//!
//! Layout (all integers little-endian, as in the eval store):
//!
//! ```text
//! [ header: 8B magic "UCEVJRNL" | u32 version | u32 flags(=0) ]
//! [ frame:  u32 len | u32 len_check | u64 payload_check | payload ]*
//! ```
//!
//! `len_check` is the low 32 bits of `fnv64` over the four `len` bytes;
//! `payload_check` is `fnv64` over the payload. The double checksum is
//! what makes every byte of the *committed* prefix load-bearing: a flip
//! in `len` can no longer masquerade as a torn tail (the frame header
//! itself fails verification before the bogus length is believed), so
//! on a fully-committed journal **any** single-byte flip fails the scan
//! in-band — the same property `tests/cache.rs` pins for the store.
//!
//! Torn tails are different from corruption. [`JournalWriter::append`]
//! builds each frame in one buffer, writes it with one `write_all`,
//! flushes, and `sync_data`s before returning — so a record either
//! committed (whole frame on disk) or the process died mid-append and
//! the file ends with an incomplete final frame. [`scan_journal`]
//! therefore accepts an *incomplete* final frame as a tear (the record
//! was never acknowledged; dropping it loses nothing that was acted
//! on), while any *complete* frame that fails a checksum is corruption
//! and comes back as an in-band error, never a panic.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::eval::manifest::MAX_ARTIFACT_BYTES;
use crate::util::fnv64;
use crate::util::json::Json;

pub const JOURNAL_MAGIC: [u8; 8] = *b"UCEVJRNL";
pub const JOURNAL_VERSION: u32 = 1;
/// Header: magic + version + flags.
pub const JOURNAL_HEADER_BYTES: u64 = 16;
/// Frame header: len + len_check + payload_check.
pub const FRAME_HEADER_BYTES: u64 = 16;
/// A journal record wraps at most one suite-shard artifact plus a small
/// JSON envelope (same slack as the fleet protocol's `MAX_LINE_BYTES`).
pub const MAX_JOURNAL_RECORD_BYTES: usize = MAX_ARTIFACT_BYTES + 4096;

fn len_check(len: u32) -> u32 {
    fnv64(&len.to_le_bytes()) as u32
}

// ===========================================================================
// Writer
// ===========================================================================

/// Append-only journal writer. Every `append` is flushed and
/// `sync_data`ed before it returns, so a record the caller acted on is
/// on disk — the write-ahead discipline the recovery path relies on.
pub struct JournalWriter {
    file: File,
    path: PathBuf,
    pos: u64,
}

impl JournalWriter {
    /// Create (truncating) a fresh journal: header only.
    pub fn create(path: impl AsRef<Path>) -> Result<JournalWriter, String> {
        let path = path.as_ref().to_path_buf();
        let mut file = File::create(&path)
            .map_err(|e| format!("journal {}: create: {e}", path.display()))?;
        let mut header = Vec::with_capacity(JOURNAL_HEADER_BYTES as usize);
        header.extend_from_slice(&JOURNAL_MAGIC);
        header.extend_from_slice(&JOURNAL_VERSION.to_le_bytes());
        header.extend_from_slice(&0u32.to_le_bytes());
        file.write_all(&header)
            .and_then(|_| file.sync_data())
            .map_err(|e| format!("journal {}: write header: {e}", path.display()))?;
        Ok(JournalWriter { file, path, pos: JOURNAL_HEADER_BYTES })
    }

    /// Reopen an existing journal for appending after [`scan_journal`]
    /// validated it. The file is truncated to `valid_end` first, so a
    /// torn tail frame is physically discarded rather than left for the
    /// next append to concatenate garbage onto.
    pub fn append_to(path: impl AsRef<Path>, valid_end: u64) -> Result<JournalWriter, String> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .open(&path)
            .map_err(|e| format!("journal {}: open for append: {e}", path.display()))?;
        file.set_len(valid_end)
            .map_err(|e| format!("journal {}: truncate torn tail: {e}", path.display()))?;
        file.seek(SeekFrom::Start(valid_end))
            .map_err(|e| format!("journal {}: seek: {e}", path.display()))?;
        Ok(JournalWriter { file, path, pos: valid_end })
    }

    /// Append one record and make it durable. On `Ok(())` the record is
    /// flushed and fsynced — callers may act on it.
    pub fn append(&mut self, payload: &[u8]) -> Result<(), String> {
        if payload.len() > MAX_JOURNAL_RECORD_BYTES {
            return Err(format!(
                "journal {}: record is {} bytes, over the {MAX_JOURNAL_RECORD_BYTES}-byte limit",
                self.path.display(),
                payload.len()
            ));
        }
        let len = payload.len() as u32;
        let mut frame = Vec::with_capacity(FRAME_HEADER_BYTES as usize + payload.len());
        frame.extend_from_slice(&len.to_le_bytes());
        frame.extend_from_slice(&len_check(len).to_le_bytes());
        frame.extend_from_slice(&fnv64(payload).to_le_bytes());
        frame.extend_from_slice(payload);
        self.file
            .write_all(&frame)
            .and_then(|_| self.file.sync_data())
            .map_err(|e| format!("journal {}: append: {e}", self.path.display()))?;
        self.pos += frame.len() as u64;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes committed so far (header included).
    pub fn pos(&self) -> u64 {
        self.pos
    }
}

// ===========================================================================
// Scan / recovery
// ===========================================================================

/// How the journal ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tail {
    /// The file ends exactly at a frame boundary.
    Clean,
    /// The file ends inside a frame that never finished committing
    /// (crash mid-append); `dropped` trailing bytes were discarded.
    Torn { dropped: u64 },
}

/// The valid prefix of a journal.
#[derive(Debug)]
pub struct JournalScan {
    /// Every committed record, in append order.
    pub records: Vec<Json>,
    /// Byte offset one past each record's frame — `ends[k]` is where a
    /// kill after record `k` leaves the file (used by the
    /// kill-at-every-boundary tests and by [`JournalWriter::append_to`]).
    pub ends: Vec<u64>,
    /// End of the valid prefix (`ends.last()`, or the header size).
    pub valid_end: u64,
    pub tail: Tail,
}

/// Read the valid prefix of a journal. Corruption in the committed
/// prefix — a checksum mismatch in any *complete* frame, a bad header,
/// an unparseable payload — is an in-band `Err`; only an incomplete
/// final frame is tolerated (as [`Tail::Torn`]). Never panics.
pub fn scan_journal(path: impl AsRef<Path>) -> Result<JournalScan, String> {
    let path = path.as_ref();
    let mut bytes = Vec::new();
    File::open(path)
        .and_then(|mut f| f.read_to_end(&mut bytes))
        .map_err(|e| format!("journal {}: read: {e}", path.display()))?;
    let whole = bytes.len() as u64;
    if whole < JOURNAL_HEADER_BYTES {
        return Err(format!(
            "journal {}: {} bytes is too short for a journal header (torn at creation? \
             delete it and start a fresh run)",
            path.display(),
            whole
        ));
    }
    if bytes[0..8] != JOURNAL_MAGIC {
        return Err(format!("journal {}: bad magic (not a run journal)", path.display()));
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != JOURNAL_VERSION {
        return Err(format!(
            "journal {}: unsupported journal version {version} (this build reads v{JOURNAL_VERSION})",
            path.display()
        ));
    }
    let flags = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
    if flags != 0 {
        return Err(format!(
            "journal {}: unsupported journal flags {flags:#x} (v1 defines none)",
            path.display()
        ));
    }

    let mut records = Vec::new();
    let mut ends = Vec::new();
    let mut pos = JOURNAL_HEADER_BYTES;
    let tail = loop {
        let remaining = whole - pos;
        if remaining == 0 {
            break Tail::Clean;
        }
        if remaining < FRAME_HEADER_BYTES {
            // not even a verifiable frame header: a tear during the
            // very first bytes of an append
            break Tail::Torn { dropped: remaining };
        }
        let p = pos as usize;
        let len = u32::from_le_bytes(bytes[p..p + 4].try_into().unwrap());
        let lc = u32::from_le_bytes(bytes[p + 4..p + 8].try_into().unwrap());
        if len_check(len) != lc {
            // the frame header itself is damaged — this is corruption,
            // not a tear: a torn append leaves a *prefix* of the frame,
            // and the header bytes were committed together
            return Err(format!(
                "journal {}: record {} at offset {pos}: frame header checksum mismatch \
                 (corrupt journal)",
                path.display(),
                records.len()
            ));
        }
        if len as usize > MAX_JOURNAL_RECORD_BYTES {
            return Err(format!(
                "journal {}: record {} at offset {pos}: length {len} is over the \
                 {MAX_JOURNAL_RECORD_BYTES}-byte limit (corrupt journal)",
                path.display(),
                records.len()
            ));
        }
        let check = u64::from_le_bytes(bytes[p + 8..p + 16].try_into().unwrap());
        if FRAME_HEADER_BYTES + len as u64 > remaining {
            // verified frame header, incomplete payload: a genuine tear
            break Tail::Torn { dropped: remaining };
        }
        let payload = &bytes[p + 16..p + 16 + len as usize];
        if fnv64(payload) != check {
            return Err(format!(
                "journal {}: record {} at offset {pos}: payload checksum mismatch \
                 (corrupt journal)",
                path.display(),
                records.len()
            ));
        }
        let text = std::str::from_utf8(payload).map_err(|e| {
            format!("journal {}: record {}: payload is not UTF-8: {e}", path.display(), records.len())
        })?;
        let json = Json::parse(text).map_err(|e| {
            format!("journal {}: record {}: bad JSON: {e}", path.display(), records.len())
        })?;
        pos += FRAME_HEADER_BYTES + len as u64;
        records.push(json);
        ends.push(pos);
    };
    Ok(JournalScan { records, ends, valid_end: pos, tail })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ucutlass_jfmt_{}_{name}", std::process::id()))
    }

    fn payload(i: u64) -> Vec<u8> {
        let mut o = Json::obj();
        o.set("kind", "test").set("i", i);
        o.to_string().into_bytes()
    }

    #[test]
    fn roundtrip_and_clean_tail() {
        let p = tmp("rt.journal");
        let mut w = JournalWriter::create(&p).unwrap();
        for i in 0..5 {
            w.append(&payload(i)).unwrap();
        }
        let scan = scan_journal(&p).unwrap();
        assert_eq!(scan.tail, Tail::Clean);
        assert_eq!(scan.records.len(), 5);
        assert_eq!(scan.valid_end, w.pos());
        for (i, r) in scan.records.iter().enumerate() {
            assert_eq!(r.get("i").and_then(|v| v.as_u64()), Some(i as u64));
        }
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn truncation_at_every_byte_yields_a_prefix_or_an_in_band_error() {
        let p = tmp("cut.journal");
        let cut = tmp("cut_m.journal");
        let mut w = JournalWriter::create(&p).unwrap();
        for i in 0..4 {
            w.append(&payload(i)).unwrap();
        }
        let base = std::fs::read(&p).unwrap();
        let full = scan_journal(&p).unwrap();
        for at in 0..base.len() {
            std::fs::write(&cut, &base[..at]).unwrap();
            match scan_journal(&cut) {
                // short-of-header prefixes fail in-band
                Err(e) => assert!((at as u64) < JOURNAL_HEADER_BYTES, "cut {at}: {e}"),
                Ok(scan) => {
                    let boundary = at as u64 == JOURNAL_HEADER_BYTES
                        || full.ends.contains(&(at as u64));
                    assert_eq!(scan.tail == Tail::Clean, boundary, "cut {at}");
                    // recovered records are exactly the committed prefix
                    assert_eq!(scan.ends, &full.ends[..scan.records.len()], "cut {at}");
                    for (a, b) in scan.records.iter().zip(&full.records) {
                        assert_eq!(a.to_string(), b.to_string(), "cut {at}");
                    }
                }
            }
        }
        for q in [&p, &cut] {
            let _ = std::fs::remove_file(q);
        }
    }

    #[test]
    fn every_single_byte_flip_in_a_committed_journal_fails_in_band() {
        let p = tmp("flip.journal");
        let m = tmp("flip_m.journal");
        let mut w = JournalWriter::create(&p).unwrap();
        for i in 0..3 {
            w.append(&payload(i)).unwrap();
        }
        let base = std::fs::read(&p).unwrap();
        for at in 0..base.len() {
            let mut b = base.clone();
            b[at] ^= 0x01;
            std::fs::write(&m, &b).unwrap();
            // a JSON-payload flip may survive as *different but valid*
            // JSON only if it also preserved the checksum — impossible
            // for a single flip under FNV-1a — so every position errs
            assert!(
                scan_journal(&m).is_err(),
                "flip at byte {at} of {} must fail recovery in-band",
                base.len()
            );
        }
        for q in [&p, &m] {
            let _ = std::fs::remove_file(q);
        }
    }

    #[test]
    fn append_to_truncates_the_torn_tail_and_continues() {
        let p = tmp("resume.journal");
        let mut w = JournalWriter::create(&p).unwrap();
        for i in 0..3 {
            w.append(&payload(i)).unwrap();
        }
        drop(w);
        // tear mid-frame: keep the valid prefix plus half a frame
        let base = std::fs::read(&p).unwrap();
        let scan = scan_journal(&p).unwrap();
        let tear = scan.ends[1] + 7;
        std::fs::write(&p, &base[..tear as usize]).unwrap();
        let scan = scan_journal(&p).unwrap();
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.tail, Tail::Torn { dropped: 7 });
        let mut w = JournalWriter::append_to(&p, scan.valid_end).unwrap();
        w.append(&payload(9)).unwrap();
        drop(w);
        let scan = scan_journal(&p).unwrap();
        assert_eq!(scan.tail, Tail::Clean);
        let got: Vec<u64> =
            scan.records.iter().map(|r| r.get("i").unwrap().as_u64().unwrap()).collect();
        assert_eq!(got, vec![0, 1, 9]);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn oversized_records_and_alien_files_are_in_band_errors() {
        let p = tmp("big.journal");
        let mut w = JournalWriter::create(&p).unwrap();
        let err = w.append(&vec![b'x'; MAX_JOURNAL_RECORD_BYTES + 1]).unwrap_err();
        assert!(err.contains("over the"), "got: {err}");
        drop(w);
        std::fs::write(&p, b"definitely not a journal").unwrap();
        let err = scan_journal(&p).unwrap_err();
        assert!(err.contains("bad magic"), "got: {err}");
        std::fs::write(&p, b"short").unwrap();
        let err = scan_journal(&p).unwrap_err();
        assert!(err.contains("too short"), "got: {err}");
        let _ = std::fs::remove_file(&p);
    }
}
