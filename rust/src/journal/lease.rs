//! Coordinator lease / heartbeat (ADR-010 §lease).
//!
//! The coordinator beats a small lease file next to the journal
//! (`<journal>.lease`) while it is alive; workers watch it and
//! self-terminate within one deadline of it going stale. This is the
//! orphan-hygiene half of crash safety: subprocess workers already die
//! on stdin EOF when a coordinator exits *cleanly*, but a `kill -9`'d
//! coordinator can leave a compute-bound or hung worker spinning
//! forever — the lease bounds that to one deadline.
//!
//! Staleness is judged *locally*: [`LeaseMonitor`] tracks when the file
//! bytes last **changed** on its own clock, so no cross-process clock
//! comparison (or mtime trust) is involved. Each beat carries a
//! monotonically increasing `seq` plus the coordinator's fencing
//! `token`, so every beat changes the bytes.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::json::Json;

fn beat_bytes(token: u64, seq: u64) -> Vec<u8> {
    let mut o = Json::obj();
    o.set("token", token).set("seq", seq).set("pid", std::process::id() as u64);
    let mut b = o.to_string().into_bytes();
    b.push(b'\n');
    b
}

/// Coordinator side: writes a beat every `interval` on a background
/// thread until dropped. A clean drop removes the lease file, so
/// workers orphaned by a *graceful* coordinator exit see staleness
/// immediately rather than after a timeout.
pub struct LeaseKeeper {
    path: PathBuf,
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl LeaseKeeper {
    /// Write the first beat synchronously (so workers spawned right
    /// after `start` returns observe a live lease), then keep beating
    /// in the background.
    pub fn start(
        path: impl AsRef<Path>,
        token: u64,
        interval: Duration,
    ) -> Result<LeaseKeeper, String> {
        let path = path.as_ref().to_path_buf();
        std::fs::write(&path, beat_bytes(token, 0))
            .map_err(|e| format!("lease {}: write: {e}", path.display()))?;
        let stop: Arc<(Mutex<bool>, Condvar)> = Arc::new((Mutex::new(false), Condvar::new()));
        let thread_stop = Arc::clone(&stop);
        let thread_path = path.clone();
        let handle = std::thread::Builder::new()
            .name("lease-keeper".into())
            .spawn(move || {
                let (lock, cv) = &*thread_stop;
                let mut seq = 1u64;
                let mut stopped = lock.lock().expect("lease stop lock");
                loop {
                    let (guard, timeout) =
                        cv.wait_timeout(stopped, interval).expect("lease stop wait");
                    stopped = guard;
                    if *stopped {
                        return;
                    }
                    if timeout.timed_out() {
                        // best-effort: a failed beat surfaces as worker
                        // staleness, which re-runs work — safe, not silent
                        let _ = std::fs::write(&thread_path, beat_bytes(token, seq));
                        seq += 1;
                    }
                }
            })
            .map_err(|e| format!("lease {}: spawn keeper: {e}", path.display()))?;
        Ok(LeaseKeeper { path, stop, handle: Some(handle) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for LeaseKeeper {
    fn drop(&mut self) {
        let (lock, cv) = &*self.stop;
        if let Ok(mut stopped) = lock.lock() {
            *stopped = true;
        }
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Worker side: polls the lease file and reports staleness once the
/// bytes have not changed for `timeout` (one coordinator deadline, by
/// default). A missing or unreadable file counts as "no beat observed"
/// — the timer keeps running, so a removed lease (clean coordinator
/// exit) also reads as stale.
#[derive(Debug, Clone)]
pub struct LeaseMonitor {
    path: PathBuf,
    timeout: Duration,
    last: Option<Vec<u8>>,
    changed_at: Instant,
}

impl LeaseMonitor {
    pub fn new(path: impl AsRef<Path>, timeout: Duration) -> LeaseMonitor {
        LeaseMonitor {
            path: path.as_ref().to_path_buf(),
            timeout,
            last: None,
            changed_at: Instant::now(),
        }
    }

    pub fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Re-read the lease; true once it has been unchanged (or absent)
    /// past the timeout.
    pub fn stale(&mut self) -> bool {
        let now = Instant::now();
        if let Ok(bytes) = std::fs::read(&self.path) {
            if self.last.as_deref() != Some(&bytes[..]) {
                self.last = Some(bytes);
                self.changed_at = now;
            }
        }
        now.duration_since(self.changed_at) > self.timeout
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("ucutlass_lease_{}_{name}", std::process::id()))
    }

    #[test]
    fn live_lease_stays_fresh_and_dropped_lease_goes_stale() {
        let p = tmp("live.lease");
        let _ = std::fs::remove_file(&p);
        let keeper = LeaseKeeper::start(&p, 3, Duration::from_millis(10)).unwrap();
        let mut mon = LeaseMonitor::new(&p, Duration::from_millis(80));
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_millis(200) {
            assert!(!mon.stale(), "a beating lease must never read stale");
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(keeper); // removes the file
        assert!(!p.exists(), "clean drop removes the lease file");
        let t1 = Instant::now();
        while !mon.stale() {
            assert!(t1.elapsed() < Duration::from_secs(5), "must go stale after drop");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn frozen_lease_goes_stale_within_the_timeout() {
        let p = tmp("frozen.lease");
        std::fs::write(&p, b"{\"token\":0,\"seq\":0}\n").unwrap();
        let mut mon = LeaseMonitor::new(&p, Duration::from_millis(50));
        assert!(!mon.stale(), "fresh observation starts the clock");
        std::thread::sleep(Duration::from_millis(80));
        assert!(mon.stale(), "unchanged bytes past the timeout are stale");
        // a new beat revives it
        std::fs::write(&p, b"{\"token\":1,\"seq\":1}\n").unwrap();
        assert!(!mon.stale());
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn missing_lease_file_reads_stale_after_the_timeout() {
        let p = tmp("missing.lease");
        let _ = std::fs::remove_file(&p);
        let mut mon = LeaseMonitor::new(&p, Duration::from_millis(30));
        assert!(!mon.stale(), "the grace window applies even with no file");
        std::thread::sleep(Duration::from_millis(60));
        assert!(mon.stale());
    }
}
