//! Crash-safe runs (ADR-010): the durable run journal, coordinator
//! lease, and recovery path behind `repro serve|sweep|schedule
//! --journal PATH [--resume]`.
//!
//! Three layers:
//!
//! * [`format`] — WAL framing in the ADR-008 family: append-only,
//!   length-prefixed, double-checksummed frames with no index footer
//!   (a journal must be readable after a crash at any byte). Every
//!   committed byte is load-bearing — a single-byte flip fails the
//!   scan in-band — while a torn tail (crash mid-append) is truncated
//!   away, never mistaken for corruption.
//! * [`run`] — the typed [`RunJournal`]: `start` / `coordinator` /
//!   `shard` / `variant` / `stop` / `done` records. Everything a run
//!   acts on is journaled (and fsynced) *first*, so `kill -9` at any
//!   event-loop iteration leaves a prefix that `--resume` replays into
//!   `SuiteMerge` / session state — output byte-identical to the
//!   uninterrupted run, zero landed keys re-measured, and coordinator
//!   incarnations fenced by token so a successor never double-charges
//!   a predecessor's in-flight work.
//! * [`lease`] — the coordinator heartbeat file workers watch so
//!   orphans self-terminate within one deadline of a coordinator
//!   `kill -9` instead of spinning forever.

pub mod format;
pub mod lease;
pub mod run;

pub use format::{
    scan_journal, JournalScan, JournalWriter, Tail, FRAME_HEADER_BYTES, JOURNAL_HEADER_BYTES,
    JOURNAL_VERSION, MAX_JOURNAL_RECORD_BYTES,
};
pub use lease::{LeaseKeeper, LeaseMonitor};
pub use run::{RunJournal, StopRecord};
