//! Evaluation metrics (paper §5.6): Fast-p curves, Attempt-Fast-p,
//! signed area between curves, geomean/median summaries, speedup
//! retention, and efficiency gain.

use crate::util::stats;

/// A Fast-p curve: percentage of problems whose speedup is ≥ r, sampled on
/// a grid of thresholds.
#[derive(Debug, Clone)]
pub struct FastP {
    pub thresholds: Vec<f64>,
    /// Values in [0, 100].
    pub pct: Vec<f64>,
}

/// Default threshold grid: log-spaced 0.05×…16× plus the exact round
/// thresholds the paper reads off (0.5×, 1×, 2×, 4×, …).
pub fn default_grid() -> Vec<f64> {
    let mut g = Vec::new();
    let mut r = 0.05f64;
    while r <= 16.0 + 1e-9 {
        g.push(r);
        r *= 1.07;
    }
    for key in [0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 8.0, 16.0] {
        if !g.iter().any(|&x: &f64| (x - key).abs() < 1e-12) {
            g.push(key);
        }
    }
    g.sort_by(|a, b| a.partial_cmp(b).unwrap());
    g
}

/// Build a Fast-p curve from per-problem speedups (unsolved problems should
/// be passed as 0.0 — they count below every threshold, as in the paper's
/// Sakana comparison).
pub fn fast_p(speedups: &[f64], grid: &[f64]) -> FastP {
    let n = speedups.len().max(1) as f64;
    let pct = grid
        .iter()
        .map(|&r| speedups.iter().filter(|&&s| s >= r).count() as f64 / n * 100.0)
        .collect();
    FastP { thresholds: grid.to_vec(), pct }
}

impl FastP {
    /// Fraction (0–100) of problems at or above threshold r.
    pub fn at(&self, r: f64) -> f64 {
        // first grid point >= r
        match self.thresholds.iter().position(|&t| t >= r) {
            Some(i) => self.pct[i],
            None => 0.0,
        }
    }
}

/// Signed area between two Fast-p curves, ∫[P_A(r) − P_B(r)] dr over the
/// grid. Positive ⇒ A lies higher/righter. Since Fast-p is a complementary
/// CDF this equals the difference in arithmetic-mean speedups (×100).
pub fn signed_area(a: &FastP, b: &FastP) -> f64 {
    assert_eq!(a.thresholds, b.thresholds);
    let diff: Vec<f64> = a.pct.iter().zip(&b.pct).map(|(x, y)| (x - y) / 100.0).collect();
    stats::trapz(&a.thresholds, &diff)
}

/// Attempt-Fast-p(r): percentage of problems whose best-so-far speedup
/// reaches ≥ r within the first `a` attempts, for a = 1..=budget.
/// `per_problem_progress[i][a]` is problem i's best speedup after a+1 attempts.
pub fn attempt_fast_p(per_problem_progress: &[Vec<f64>], r: f64) -> Vec<f64> {
    if per_problem_progress.is_empty() {
        return vec![];
    }
    let budget = per_problem_progress.iter().map(|v| v.len()).max().unwrap();
    let n = per_problem_progress.len() as f64;
    (0..budget)
        .map(|a| {
            per_problem_progress
                .iter()
                .filter(|prog| prog.get(a).copied().unwrap_or(0.0) >= r)
                .count() as f64
                / n
                * 100.0
        })
        .collect()
}

/// Scalar summaries used throughout §6: geomean with the PyTorch-seed 1.0
/// fallback for unsolved problems, and median.
pub fn geomean_speedup(speedups: &[f64]) -> f64 {
    stats::geomean_with_fallback(speedups, 1.0)
}

pub fn median_speedup(speedups: &[f64]) -> f64 {
    stats::median(speedups)
}

/// Speedup retention of a scheduling policy vs the fixed-budget run.
pub fn retention(policy_geomean: f64, fixed_geomean: f64) -> f64 {
    if fixed_geomean == 0.0 {
        return 0.0;
    }
    policy_geomean / fixed_geomean
}

/// Efficiency gain (paper §5.6): (g_policy/g_fixed) × (τ_fixed/τ_policy).
pub fn efficiency_gain(
    policy_geomean: f64,
    fixed_geomean: f64,
    policy_tokens: f64,
    fixed_tokens: f64,
) -> f64 {
    if fixed_geomean <= 0.0 || policy_tokens <= 0.0 {
        return 0.0;
    }
    (policy_geomean / fixed_geomean) * (fixed_tokens / policy_tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_p_monotone_decreasing() {
        let grid = default_grid();
        let c = fast_p(&[0.5, 1.0, 2.0, 4.0], &grid);
        for w in c.pct.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!((c.at(1.0) - 75.0).abs() < 1e-9);
        assert!((c.at(2.0) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn unsolved_counts_as_zero() {
        let grid = default_grid();
        let c = fast_p(&[0.0, 2.0], &grid);
        assert!((c.at(0.05) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn signed_area_positive_for_dominant_curve() {
        let grid = default_grid();
        let a = fast_p(&[2.0, 3.0, 4.0], &grid);
        let b = fast_p(&[1.0, 1.5, 2.0], &grid);
        assert!(signed_area(&a, &b) > 0.0);
        assert!(signed_area(&b, &a) < 0.0);
        assert!((signed_area(&a, &a)).abs() < 1e-12);
    }

    #[test]
    fn signed_area_approximates_mean_difference() {
        let grid = default_grid();
        let a = fast_p(&[2.0, 4.0], &grid);
        let b = fast_p(&[1.0, 2.0], &grid);
        // mean diff = (3.0 - 1.5) = 1.5; grid truncation below 0.05 loses a little
        let area = signed_area(&a, &b);
        assert!((area - 1.5).abs() < 0.15, "area={area}");
    }

    #[test]
    fn attempt_fast_p_rises() {
        let prog = vec![
            vec![0.0, 1.0, 2.5, 2.5],
            vec![0.0, 0.0, 1.0, 3.0],
        ];
        let curve = attempt_fast_p(&prog, 2.0);
        assert_eq!(curve, vec![0.0, 0.0, 50.0, 100.0]);
        for w in curve.windows(2) {
            assert!(w[1] >= w[0], "best-so-far curves are monotone");
        }
    }

    #[test]
    fn efficiency_gain_above_one_when_savings_beat_loss() {
        // 96% retention with 43% token savings → 0.96/0.57 ≈ 1.68 (paper's best)
        let g = efficiency_gain(0.96 * 2.0, 2.0, 0.57, 1.0);
        assert!((g - 1.684).abs() < 0.01, "g={g}");
    }
}
