//! MANTIS: the orchestrated SOL-first workflow (paper §4.2) —
//! Measure–Analyze–Nominate–Triage–Implement–Summarize.
//!
//! * **Measure** — profile the current best kernel (simulated NCU).
//! * **Analyze** — SOL gap `g = t_best / t_SOL` + bottleneck attribution.
//! * **Nominate** — candidate hypotheses with causal links to bottlenecks.
//! * **Triage** — rank by the gap-aware ROI formula
//!   `ROI(h) = Ŝ(h)^(1+max(0, log10(g/5))) / (R_impl · R_perf)`:
//!   ambition amplifies when far from SOL, incrementalism near it.
//! * **Implement** — a fixed attempt budget per selected hypothesis,
//!   running the shared Generate–Compile–Test–Profile engine.
//! * **Summarize** — distill outcomes into cross-problem memory that later
//!   nominations retrieve.
//!
//! Budgets follow Table 2: 5 iterations × 2 hypotheses × 4 attempts = 40.
//! The component ablations of Table 3 are expressed by [`MantisConfig`].

use std::collections::HashMap;

use crate::agent::controller::{
    modifiers, quality_gain, run_attempt, AgentState, Env, Modifiers, VariantSpec,
};
use crate::agent::policy::{self, OptMove};
use crate::agent::runlog::ProblemRun;
use crate::agent::session::StepResult;
use crate::eval::{EvalRequest, Evaluator};
use crate::perfmodel::{CandidateConfig, ConfigBatch};
use crate::util::json::Json;
use crate::util::rng::{stream, MeasureSeq, Pcg32, StreamPath};

/// Which MANTIS phases are active (Table 3 ablations).
#[derive(Debug, Clone, Copy)]
pub struct MantisConfig {
    /// SOL analysis feeds nomination + the gap exponent (off = "MNTIS").
    pub analyze: bool,
    /// ROI-based ranking (off = "MANIS": random pick among nominations).
    pub triage: bool,
    /// Post-iteration summaries (off = "MANTI": also disables memory).
    pub summarize: bool,
    /// Summaries persist across problems (off = "MANTIS-noXmem").
    pub cross_memory: bool,
}

impl Default for MantisConfig {
    fn default() -> Self {
        MantisConfig { analyze: true, triage: true, summarize: true, cross_memory: true }
    }
}

impl MantisConfig {
    pub fn ablation(name: &str) -> MantisConfig {
        let mut c = MantisConfig::default();
        match name {
            "MNTIS" => c.analyze = false,
            "MANIS" => c.triage = false,
            "MANTI" => {
                c.summarize = false;
                c.cross_memory = false;
            }
            "MANTIS-noXmem" => c.cross_memory = false,
            _ => {}
        }
        c
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("analyze", self.analyze)
            .set("triage", self.triage)
            .set("summarize", self.summarize)
            .set("cross_memory", self.cross_memory);
        o
    }

    pub fn from_json(j: &Json) -> Result<MantisConfig, String> {
        let field = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_bool())
                .ok_or_else(|| format!("mantis config: missing {k}"))
        };
        Ok(MantisConfig {
            analyze: field("analyze")?,
            triage: field("triage")?,
            summarize: field("summarize")?,
            cross_memory: field("cross_memory")?,
        })
    }
}

/// Iterations × hypotheses × attempts (Table 2).
pub const ITERATIONS: u32 = 5;
pub const HYPOTHESES_PER_ITER: usize = 2;
pub const ATTEMPTS_PER_HYPOTHESIS: u32 = 4;

/// The gap-aware ROI formula (paper §4.2 step 4).
pub fn roi(est_speedup: f64, gap: f64, r_impl: f64, r_perf: f64) -> f64 {
    let exponent = 1.0 + (gap / 5.0).log10().max(0.0);
    est_speedup.max(1e-6).powf(exponent) / (r_impl * r_perf)
}

/// A nominated optimization hypothesis.
#[derive(Debug, Clone)]
pub struct Hypothesis {
    pub mv: OptMove,
    /// The model's own speedup estimate Ŝ(h) (noisy).
    pub est_speedup: f64,
    /// Implementation risk R_impl ∈ [0.5, 2.5].
    pub r_impl: f64,
    /// Performance risk R_perf ∈ [0.5, 2.5].
    pub r_perf: f64,
    pub roi: f64,
}

/// Per-move-kind outcome statistics distilled by Summarize; retrieved by
/// later Nominate phases (the paper's cross-problem memory).
#[derive(Debug, Clone, Default)]
pub struct CrossMemory {
    /// move-kind key → (times it improved, times it did not).
    stats: HashMap<&'static str, (u32, u32)>,
}

fn move_key(mv: OptMove) -> &'static str {
    match mv {
        OptMove::Tile(_) => "tile",
        OptMove::UseFp16 => "fp16",
        OptMove::UseBf16 => "bf16",
        OptMove::FuseAll => "fuse",
        OptMove::SchedulerPersistent => "persistent",
        OptMove::SchedulerStreamK => "streamk",
        OptMove::MoreStages => "stages",
        OptMove::ImproveCode => "code",
    }
}

impl CrossMemory {
    pub fn record(&mut self, mv: OptMove, improved: bool) {
        let e = self.stats.entry(move_key(mv)).or_insert((0, 0));
        if improved {
            e.0 += 1;
        } else {
            e.1 += 1;
        }
    }

    /// Multiplicative prior on a hypothesis's estimate from past outcomes.
    pub fn prior(&self, mv: OptMove) -> f64 {
        match self.stats.get(move_key(mv)) {
            None => 1.0,
            Some((s, f)) => {
                let n = (s + f) as f64;
                let rate = *s as f64 / n;
                // Laplace-ish smoothing, bounded influence
                1.0 + 0.5 * (rate - 0.5) * (n / (n + 2.0))
            }
        }
    }

    pub fn len(&self) -> usize {
        self.stats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stats.is_empty()
    }
}

/// Implementation/performance risk scores per move kind.
fn risks(mv: OptMove) -> (f64, f64) {
    match mv {
        OptMove::Tile(_) => (0.7, 0.9),
        OptMove::UseFp16 | OptMove::UseBf16 => (1.2, 1.0), // precision risk
        OptMove::FuseAll => (1.6, 0.8),                    // hard to implement, reliable payoff
        OptMove::SchedulerPersistent | OptMove::SchedulerStreamK => (0.9, 1.1),
        OptMove::MoreStages => (0.6, 1.2),
        OptMove::ImproveCode => (1.4, 1.3),
    }
}

/// Resumable orchestrated-MANTIS session (ADR-002): the 5 × 2 × 4 nested
/// loop of the paper's Table 2 unrolled into a state machine that yields
/// exactly one Implement attempt per `step()`. Phase boundaries are
/// preserved: Measure/Analyze/Nominate/Triage run lazily when the previous
/// iteration's hypothesis queue is exhausted, and Summarize fires after a
/// hypothesis's last attempt — the RNG consumption order is identical to
/// the original loop, so driving a session to exhaustion reproduces
/// [`run_orchestrated`] bit-for-bit and early stops yield exact prefixes.
pub struct MantisSession<'a> {
    env: Env<'a>,
    spec: VariantSpec,
    cfg: MantisConfig,
    memory: CrossMemory,
    mods: Modifiers,
    pidx: usize,
    rng: Pcg32,
    state: AgentState,
    plans: crate::dsl::PlanCache,
    attempts: Vec<crate::agent::AttemptRecord>,
    t_ref_ms: f64,
    /// Iterations whose Nominate/Triage phase has already run.
    iters_started: u32,
    /// Current iteration's triaged hypotheses.
    selected: Vec<Hypothesis>,
    hyp_idx: usize,
    /// Attempts already spent on the current hypothesis.
    hyp_attempt: u32,
    /// `state.best_time_ms` when the current hypothesis started (Summarize
    /// records whether the hypothesis improved on it).
    hyp_before_best: f64,
}

impl<'a> MantisSession<'a> {
    pub fn new(
        env: Env<'a>,
        spec: &VariantSpec,
        pidx: usize,
        seed: u64,
        cfg: MantisConfig,
        memory: CrossMemory,
    ) -> Self {
        let rng = Pcg32::derive(seed, &[stream::MANTIS, spec.stream_id(), pidx as u64]);
        let mods = modifiers(spec);
        // One derived noise stream per measurement (ADR-003); the baseline
        // takes stream 0, Implement-phase measurements continue.
        let mut measure = MeasureSeq::new(StreamPath::new(
            seed,
            &[stream::MEASURE, stream::MANTIS, spec.stream_id(), pidx as u64],
        ));
        // scalar fast path (ADR-005): no response struct, no key strings
        let t_ref_ms = env
            .evaluator()
            .value(&EvalRequest::measured_baseline(pidx, measure.next_stream()));
        let state = AgentState {
            best_time_ms: f64::INFINITY,
            t_ref_ms,
            best_cfg: None,
            gamed: None,
            consecutive_failures: 0,
            tokens: 0,
            measure,
            prune: crate::analyze::PruneGate::new(),
        };
        MantisSession {
            env,
            spec: *spec,
            cfg,
            memory,
            mods,
            pidx,
            rng,
            state,
            // Per-problem plan cache shared across all iterations/
            // hypotheses: revisited configurations skip re-lowering
            // (ADR-001).
            plans: crate::dsl::PlanCache::new(),
            attempts: Vec::with_capacity((ITERATIONS * 8) as usize),
            t_ref_ms,
            iters_started: 0,
            selected: Vec::new(),
            hyp_idx: 0,
            hyp_attempt: 0,
            hyp_before_best: f64::INFINITY,
        }
    }

    /// Measure + Analyze + Nominate + Triage for the next iteration.
    fn nominate(&mut self) {
        let sol = &self.env.sols[self.pidx];
        let tier = self.spec.tier.params();

        // ---- Measure + Analyze -------------------------------------------
        let t_best = if self.state.best_time_ms.is_finite() {
            self.state.best_time_ms
        } else {
            self.t_ref_ms
        };
        let gap = if self.cfg.analyze { sol.gap(t_best) } else { 1.0 };

        // ---- Nominate -----------------------------------------------------
        let base = self
            .state
            .best_cfg
            .clone()
            .unwrap_or_else(|| CandidateConfig::library((128, 128, 64), crate::dsl::DType::Fp32));
        let mut pool = policy::moves_from(&base);
        if self.cfg.analyze {
            let filtered: Vec<OptMove> = pool
                .iter()
                .copied()
                .filter(|m| policy::targets_bottleneck(*m, sol.bottleneck))
                .collect();
            if !filtered.is_empty() {
                pool = filtered;
            }
        }
        let qgain = quality_gain(self.spec.tier);
        // orchestration's structured artifacts tighten the model's own
        // estimates beyond in-prompt steering
        let sigma = tier.estimate_sigma * if self.cfg.analyze { 0.3 } else { 1.0 };
        // One batched evaluation per Nominate round (ADR-003): slot 0 is
        // the current base, slots 1..=k the candidate of each nominated
        // move. With no backend override the pool rides the problem's
        // pre-compiled evaluator over a struct-of-arrays batch (ADR-006);
        // with an override (record/replay) every candidate goes through
        // the request path so the backend observes it (ADR-004). The two
        // paths are bitwise identical, so the RNG draws below — and every
        // downstream artifact — do not depend on which one ran.
        let oracle = self.env.evaluator();
        let est_ms: Vec<f64> = match oracle.direct() {
            Some(analytic) => {
                let mut batch = ConfigBatch::with_capacity(pool.len() + 1);
                batch.push(&base);
                for &mv in &pool {
                    batch.push(&policy::apply_move(&base, mv, qgain));
                }
                let mut out = Vec::new();
                analytic.candidate_batch_into(self.pidx, &batch, &mut out);
                out
            }
            None => {
                let reqs: Vec<EvalRequest> = std::iter::once(base.clone())
                    .chain(pool.iter().map(|&mv| policy::apply_move(&base, mv, qgain)))
                    .map(|cfg| EvalRequest::candidate(self.pidx, cfg))
                    .collect();
                oracle.eval_batch(&reqs).iter().map(|r| r.value).collect()
            }
        };
        let t_now = est_ms[0];
        let mut hyps: Vec<Hypothesis> = pool
            .iter()
            .zip(&est_ms[1..])
            .map(|(&mv, &t_new)| {
                let mem_prior = if self.cfg.summarize { self.memory.prior(mv) } else { 1.0 };
                let est = (t_now / t_new) * self.rng.lognormal_noise(sigma) * mem_prior;
                let (ri, rp) = risks(mv);
                Hypothesis { mv, est_speedup: est, r_impl: ri, r_perf: rp, roi: roi(est, gap, ri, rp) }
            })
            .collect();

        // ---- Triage ---------------------------------------------------------
        if self.cfg.triage {
            hyps.sort_by(|a, b| b.roi.partial_cmp(&a.roi).unwrap());
        } else {
            self.rng.shuffle(&mut hyps);
        }
        self.selected = hyps.into_iter().take(HYPOTHESES_PER_ITER).collect();
        self.hyp_idx = 0;
        self.hyp_attempt = 0;
        // phase overhead tokens (structured artifacts between phases)
        self.state.tokens += (8_000.0 * self.mods.tokens_mult) as u64;
        self.iters_started += 1;
    }

    /// Execute one Implement attempt; `None` once all iterations are done.
    pub fn step(&mut self) -> Option<StepResult> {
        if self.hyp_idx >= self.selected.len() {
            if self.iters_started >= ITERATIONS {
                return None;
            }
            self.nominate();
            if self.selected.is_empty() {
                // no viable hypothesis nominated: the iteration spends no
                // Implement attempts; recurse into the next iteration
                return self.step();
            }
        }
        let steering = if self.cfg.analyze { Some(&self.env.sols[self.pidx]) } else { None };
        if self.hyp_attempt == 0 {
            self.hyp_before_best = self.state.best_time_ms;
        }
        // first attempt executes the hypothesis; retries refine freely
        let forced = if self.hyp_attempt == 0 { Some(self.selected[self.hyp_idx].mv) } else { None };
        let attempt_no = self.attempts.len() as u32;
        let rec = run_attempt(
            &self.env,
            &self.spec,
            &self.mods,
            self.pidx,
            attempt_no,
            &mut self.state,
            steering,
            forced,
            &mut self.plans,
            &mut self.rng,
        );
        let result =
            StepResult { attempt: attempt_no, time_ms: rec.outcome.time_ms(), tokens: rec.tokens };
        self.attempts.push(rec);
        self.hyp_attempt += 1;
        if self.hyp_attempt == ATTEMPTS_PER_HYPOTHESIS {
            // ---- Summarize ------------------------------------------------
            if self.cfg.summarize {
                let mv = self.selected[self.hyp_idx].mv;
                self.memory.record(mv, self.state.best_time_ms < self.hyp_before_best);
            }
            self.hyp_idx += 1;
            self.hyp_attempt = 0;
        }
        Some(result)
    }

    pub fn attempts_done(&self) -> usize {
        self.attempts.len()
    }

    pub fn pidx(&self) -> usize {
        self.pidx
    }

    pub fn t_ref_ms(&self) -> f64 {
        self.t_ref_ms
    }

    pub fn env(&self) -> &Env<'a> {
        &self.env
    }

    /// Consume the session, returning the run and the final memory (the
    /// serial cross-problem chain writes it back; independent sessions
    /// drop it).
    pub fn finish(self) -> (ProblemRun, CrossMemory) {
        let run = ProblemRun {
            problem_idx: self.pidx,
            t_ref_ms: self.t_ref_ms,
            t_sol_ms: self.env.sols[self.pidx].t_sol_ms,
            t_sol_fp16_ms: self.env.sols[self.pidx].t_sol_fp16_ms,
            attempts: self.attempts,
        };
        (run, self.memory)
    }
}

/// Orchestrated MANTIS on one problem, driven to its full budget. `ctx`
/// carries the ablation config and (when cross-memory is on) the memory
/// shared across problems; the memory is snapshotted into the session and
/// written back on completion, which is observably identical to the old
/// in-place mutation because the serial chain runs one problem at a time.
pub fn run_orchestrated(
    env: &Env,
    spec: &VariantSpec,
    pidx: usize,
    seed: u64,
    ctx: Option<(&MantisConfig, &mut CrossMemory)>,
) -> ProblemRun {
    let cfg = ctx.as_ref().map(|(c, _)| **c).unwrap_or_default();
    let mem_in = ctx.as_ref().map(|(_, m)| (**m).clone()).unwrap_or_default();
    let mut session = MantisSession::new(*env, spec, pidx, seed, cfg, mem_in);
    while session.step().is_some() {}
    let (run, mem_out) = session.finish();
    if let Some((_, m)) = ctx {
        *m = mem_out;
    }
    run
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agent::{ControllerKind, ModelTier};
    use crate::kernelbench::suite;
    use crate::perfmodel::{CompiledCostModel, PerfModel};
    use crate::sol::{analyze, SolAnalysis, H100_SXM};

    #[test]
    fn roi_formula_matches_paper() {
        // Near SOL (g <= 5): exponent 1 → plain Ŝ/(Ri·Rp)
        assert!((roi(2.0, 3.0, 1.0, 1.0) - 2.0).abs() < 1e-12);
        // Far from SOL (g = 50): exponent 1 + log10(10) = 2
        assert!((roi(2.0, 50.0, 1.0, 1.0) - 4.0).abs() < 1e-12);
        // Risk divides
        assert!((roi(2.0, 3.0, 2.0, 1.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn roi_amplifies_ambition_when_far() {
        // ambitious (3×) vs incremental (1.3×), both risky vs safe
        let near_ambitious = roi(3.0, 2.0, 2.0, 1.5);
        let near_safe = roi(1.3, 2.0, 0.7, 0.9);
        let far_ambitious = roi(3.0, 100.0, 2.0, 1.5);
        let far_safe = roi(1.3, 100.0, 0.7, 0.9);
        // far from SOL the ambitious hypothesis gains relative attractiveness
        assert!(far_ambitious / far_safe > near_ambitious / near_safe);
    }

    #[test]
    fn memory_prior_learns() {
        let mut m = CrossMemory::default();
        for _ in 0..8 {
            m.record(OptMove::UseFp16, true);
        }
        for _ in 0..8 {
            m.record(OptMove::MoreStages, false);
        }
        assert!(m.prior(OptMove::UseFp16) > 1.1);
        assert!(m.prior(OptMove::MoreStages) < 0.9);
        assert!((m.prior(OptMove::FuseAll) - 1.0).abs() < 1e-12);
    }

    fn fixture(
    ) -> (PerfModel, Vec<crate::kernelbench::Problem>, Vec<SolAnalysis>, CompiledCostModel) {
        let model = PerfModel::new(H100_SXM.clone());
        let problems = suite();
        let sols = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
        let compiled = CompiledCostModel::compile(&model, &problems);
        (model, problems, sols, compiled)
    }

    #[test]
    fn orchestrated_respects_total_budget() {
        let (model, problems, sols, compiled) = fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mid);
        let run = run_orchestrated(&env, &spec, 0, 9, None);
        assert_eq!(run.attempts.len(), 40, "5 iters × 2 hyps × 4 attempts");
    }

    #[test]
    fn ablation_configs() {
        assert!(!MantisConfig::ablation("MNTIS").analyze);
        assert!(!MantisConfig::ablation("MANIS").triage);
        let manti = MantisConfig::ablation("MANTI");
        assert!(!manti.summarize && !manti.cross_memory);
        let noxmem = MantisConfig::ablation("MANTIS-noXmem");
        assert!(noxmem.summarize && !noxmem.cross_memory);
    }

    #[test]
    fn cross_memory_threads_across_problems() {
        let (model, problems, sols, compiled) = fixture();
        let env = Env::new(&model, &problems, &sols, &compiled);
        let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mid);
        let cfg = MantisConfig::default();
        let mut mem = CrossMemory::default();
        run_orchestrated(&env, &spec, 0, 1, Some((&cfg, &mut mem)));
        assert!(!mem.is_empty(), "summarize should have distilled outcomes");
    }
}
