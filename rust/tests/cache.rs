//! Persistent eval-store tests (ADR-008 acceptance): a warm-cache re-run
//! must produce byte-identical RunLogs with zero live evaluator calls —
//! at `--jobs 1`, `--jobs 4`, and through `repro serve` with a
//! coordinator-side cache — the binary store must round-trip losslessly
//! through the JSONL v2 bridge, `EvalKey::shard` partitioning must
//! reconstruct the full key set, and every corrupt input must come back
//! as an in-band error, never a panic.

use std::collections::HashSet;
use std::path::PathBuf;
use std::process::Command;
use std::sync::Arc;
use std::time::Duration;

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::policy::TILES;
use ucutlass_repro::agent::{ModelTier, RunLog};
use ucutlass_repro::dsl::DType;
use ucutlass_repro::eval::manifest::SuiteWork;
use ucutlass_repro::eval::{
    EvalKey, EvalRequest, EvalResponse, Evaluator, OwnedAnalytic, TraceEvaluator,
};
use ucutlass_repro::exec::eval_variants;
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::fleet::{run_fleet, thread_worker_factory, EventLog, FaultPlan, FleetConfig};
use ucutlass_repro::kernelbench::suite;
use ucutlass_repro::mantis::MantisConfig;
use ucutlass_repro::perfmodel::CandidateConfig;
use ucutlass_repro::store::{
    cache_session, compact_store, export_jsonl, import_jsonl, merge_stores, shard_store,
    verify_store, CacheMode, CacheSessionMode, CachedEvaluator, EvalStore, StoreWriter,
    MAX_RECORD_BYTES, STORE_VERSION,
};
use ucutlass_repro::util::json::Json;
use ucutlass_repro::util::rng::{stream, StreamPath};
use ucutlass_repro::util::{fnv64, prop};

fn exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ucutlass_cache_{}_{name}", std::process::id()))
}

/// One flat variant + one orchestrated default, as in the record/replay
/// golden test: together they cover both task shapes of ADR-002.
fn rr_work() -> Vec<(VariantSpec, Option<MantisConfig>)> {
    vec![
        (VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mini), None),
        (
            VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini),
            Some(MantisConfig::default()),
        ),
    ]
}

/// Deterministic request set covering every `MeasureKind`. 34 requests
/// keeps all content-hash keys distinct: the (kind, problem) cycles only
/// re-align at index 35, and the measured kinds carry the index in their
/// stream path.
fn sample_requests() -> Vec<EvalRequest> {
    let dtypes = [DType::Fp32, DType::Fp16, DType::Bf16];
    (0..34)
        .map(|i| {
            let p = i % 7;
            let cfg = CandidateConfig::library(TILES[i % TILES.len()], dtypes[i % 3]);
            let at = StreamPath::new(
                42,
                &[stream::MEASURE, stream::PROP_CASE, p as u64, i as u64],
            );
            match i % 5 {
                0 => EvalRequest::baseline(p),
                1 => EvalRequest::measured_baseline(p, at),
                2 => EvalRequest::candidate(p, cfg),
                3 => EvalRequest::measured(p, cfg, at),
                _ => EvalRequest::sol_gap(p),
            }
        })
        .collect()
}

/// The sample requests answered by the live analytic backend, plus two
/// synthetic records it never produces: an error with a multi-line
/// unicode detail, and a pass with a float that exposes sloppy decimal
/// round-trips.
fn sample_pairs() -> Vec<(EvalRequest, EvalResponse)> {
    let reqs = sample_requests();
    let live = OwnedAnalytic::new();
    let resps = live.eval_batch(&reqs);
    let mut pairs: Vec<(EvalRequest, EvalResponse)> = reqs.into_iter().zip(resps).collect();
    let cfg = CandidateConfig::library(TILES[0], DType::Bf16);
    let e = EvalRequest::candidate(1, cfg.clone()).with_hash("feedface00000001");
    let e_resp =
        EvalResponse::error(e.eval_key(), "compile failed:\n  line 2 \"quoted\" \u{2713}");
    pairs.push((e, e_resp));
    let o = EvalRequest::candidate(2, cfg).with_hash("feedface00000002");
    let o_resp = EvalResponse::ok(o.eval_key(), 0.1 + 0.2);
    pairs.push((o, o_resp));
    pairs
}

fn build_store(path: &PathBuf, pairs: &[(EvalRequest, EvalResponse)]) {
    let mut w = StoreWriter::create(path).unwrap_or_else(|e| panic!("{e}"));
    for (req, resp) in pairs {
        assert!(w.append(req, resp).unwrap_or_else(|e| panic!("{e}")));
    }
    w.finish().unwrap_or_else(|e| panic!("{e}"));
}

// ---------------------------------------------------------------------------
// The golden property: warm re-runs are byte-identical with zero live calls

#[test]
fn cached_run_warm_rerun_is_byte_identical_with_zero_live_evals() {
    let path = tmp("golden.store");
    let _ = std::fs::remove_file(&path);
    let work = rr_work();
    let seed = 2025;

    // reference: the plain analytic run
    let bench = Bench::new();
    let reference: Vec<RunLog> = eval_variants(&bench, &work, seed, 1);

    // cold run under --jobs 4: write-through must be transparent
    {
        let mut bench_rec = Bench::new();
        let (oracle, mon) = cache_session(CacheSessionMode::WriteThrough, path.clone()).unwrap_or_else(|e| panic!("{e}"));
        bench_rec.set_oracle(oracle);
        let recorded = eval_variants(&bench_rec, &work, seed, 4);
        assert_eq!(recorded, reference, "write-through must not perturb the run");
        assert!(mon.live() > 0, "cold store: everything is measured live");
        assert!(mon.writes() > 0);
        assert_eq!(mon.misses(), 0, "live fall-through is not a miss");
        drop(bench_rec); // dropping the evaluator writes the index + trailer
        assert_eq!(mon.io_error(), None);
    }

    let store = EvalStore::open(&path).unwrap_or_else(|e| panic!("{e}"));
    assert!(store.len() > 0);
    verify_store(&store).unwrap_or_else(|e| panic!("{e}"));
    drop(store);

    // warm re-runs, fully offline: zero live evaluator calls, zero
    // misses, byte-identical RunLogs — serial and parallel
    for jobs in [1usize, 4] {
        let mut bench_rep = Bench::new();
        let (oracle, mon) = cache_session(CacheSessionMode::Offline, path.clone()).unwrap_or_else(|e| panic!("{e}"));
        bench_rep.set_oracle(oracle);
        let replayed = eval_variants(&bench_rep, &work, seed, jobs);
        assert_eq!(mon.live(), 0, "jobs={jobs}: offline has no live backend");
        assert_eq!(mon.misses(), 0, "jobs={jobs}: first miss: {:?}", mon.first_miss());
        assert!(mon.hits() > 0);
        mon.check().unwrap_or_else(|e| panic!("jobs={jobs}: {e}"));
        assert_eq!(replayed, reference, "jobs={jobs}: field-for-field exact");
        for (r, x) in replayed.iter().zip(&reference) {
            assert_eq!(
                r.to_json().to_string(),
                x.to_json().to_string(),
                "jobs={jobs}: persisted artifacts must be byte-identical"
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn write_through_extend_serves_landed_records_and_appends_only_fresh_keys() {
    let path = tmp("extend.store");
    let _ = std::fs::remove_file(&path);
    let seed = 2025;
    let subset: Vec<(VariantSpec, Option<MantisConfig>)> =
        vec![(VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mini), None)];

    // session 1: record the subset
    {
        let mut b = Bench::new();
        let (oracle, mon) = cache_session(CacheSessionMode::WriteThrough, path.clone()).unwrap_or_else(|e| panic!("{e}"));
        b.set_oracle(oracle);
        let _ = eval_variants(&b, &subset, seed, 1);
        drop(b);
        assert!(mon.writes() > 0);
        assert_eq!(mon.io_error(), None);
    }
    let bytes1 = std::fs::read(&path).unwrap();
    let store1 = EvalStore::open(&path).unwrap_or_else(|e| panic!("{e}"));
    let keys1: Vec<EvalKey> = store1.keys().collect();
    drop(store1);

    // session 2: the same subset again — extend seeds its dedup state
    // from the offset index (no payload re-read, no JSON re-parse), the
    // run is served entirely from the store, nothing is appended, and
    // finish() rewrites the identical index: the file is byte-stable
    {
        let mut b = Bench::new();
        let (oracle, mon) = cache_session(CacheSessionMode::WriteThrough, path.clone()).unwrap_or_else(|e| panic!("{e}"));
        b.set_oracle(oracle);
        let rerun = eval_variants(&b, &subset, seed, 1);
        drop(b);
        assert!(!rerun.is_empty());
        assert_eq!(mon.writes(), 0, "every key already landed");
        assert_eq!(mon.live(), 0);
        assert!(mon.hits() > 0);
        assert_eq!(mon.io_error(), None);
    }
    assert_eq!(
        std::fs::read(&path).unwrap(),
        bytes1,
        "a no-new-keys extension must leave the store byte-identical"
    );

    // session 3: a superset — only the new variant's keys go live and
    // get appended; every previously landed record keeps serving
    {
        let mut b = Bench::new();
        let (oracle, mon) = cache_session(CacheSessionMode::WriteThrough, path.clone()).unwrap_or_else(|e| panic!("{e}"));
        b.set_oracle(oracle);
        let _ = eval_variants(&b, &rr_work(), seed, 1);
        drop(b);
        assert!(mon.writes() > 0, "the second variant brings fresh keys");
        assert!(mon.hits() > 0, "the subset's keys come from the store");
        assert_eq!(mon.io_error(), None);
    }
    let store = EvalStore::open(&path).unwrap_or_else(|e| panic!("{e}"));
    assert!(store.len() > keys1.len());
    for k in &keys1 {
        assert!(store.contains(*k), "extension must not orphan key {k}");
    }
    verify_store(&store).unwrap_or_else(|e| panic!("{e}"));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn thread_fleet_with_shared_offline_cache_matches_single_process_run() {
    let path = tmp("fleet.store");
    let _ = std::fs::remove_file(&path);
    let bench = Bench::new();
    let work = SuiteWork {
        seed: 77,
        problems: bench.problems.len(),
        work: vec![
            (VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini), None),
            (
                VariantSpec::new(ControllerKind::OrchestratedSol, false, ModelTier::Mini),
                Some(MantisConfig::default()),
            ),
        ],
    };
    let reference = Json::Arr(
        eval_variants(&bench, &work.work, work.seed, 1).iter().map(|l| l.to_json()).collect(),
    )
    .to_string();

    // record the whole job once, plus the coordinator's admission
    // baselines, so the store covers every fleet-side request
    {
        let mut b = Bench::new();
        let (oracle, mon) = cache_session(CacheSessionMode::WriteThrough, path.clone()).unwrap_or_else(|e| panic!("{e}"));
        b.set_oracle(oracle);
        let _ = eval_variants(&b, &work.work, work.seed, 1);
        let baselines: Vec<EvalRequest> =
            (0..b.problems.len()).map(EvalRequest::baseline).collect();
        let _ = b.evaluator().eval_batch(&baselines);
        drop(b);
        assert_eq!(mon.io_error(), None);
    }
    let store_bytes = std::fs::read(&path).unwrap();

    // coordinator + both in-process workers share one offline session
    let mut shared = Bench::new();
    let (oracle, mon) = cache_session(CacheSessionMode::Offline, path.clone()).unwrap_or_else(|e| panic!("{e}"));
    shared.set_oracle(oracle);
    let shared = Arc::new(shared);
    let cfg = FleetConfig {
        workers: 2,
        deadline: Duration::from_secs(180),
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(50),
        ..FleetConfig::default()
    };
    let events = EventLog::new();
    let out = run_fleet(
        &shared,
        &work,
        &cfg,
        thread_worker_factory(Arc::clone(&shared), vec![FaultPlan::none(); 2]),
        &events,
    )
    .unwrap_or_else(|e| panic!("offline-cached fleet must converge: {e}"));
    let got = Json::Arr(out.logs.iter().map(|l| l.to_json()).collect()).to_string();
    assert_eq!(got, reference, "byte-identical to one process, zero re-measurement");
    assert_eq!(mon.live(), 0);
    assert_eq!(mon.misses(), 0, "first miss: {:?}", mon.first_miss());
    assert!(mon.hits() > 0);
    mon.check().unwrap_or_else(|e| panic!("{e}"));
    // single-writer discipline: fleets never write the store
    assert_eq!(std::fs::read(&path).unwrap(), store_bytes);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_cli_offline_cache_end_to_end_zero_live_zero_misses() {
    let store = tmp("serve.store");
    let out_path = tmp("serve_out.json");
    let _ = std::fs::remove_file(&store);

    // 1. record: one single-process cached run of the same spec + seed
    let output = Command::new(exe())
        .args(["run", "--tier", "mini", "--seed", "9", "--cache"])
        .arg(&store)
        .output()
        .expect("run repro run --cache");
    assert!(
        output.status.success(),
        "recording run must exit 0; stderr: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(stdout.contains("cache "), "prints the session summary: {stdout}");

    // 1b. top up the coordinator's admission baselines via a
    // write-through extension (already-covered keys dedup to no-ops)
    {
        let (oracle, mon) = cache_session(CacheSessionMode::WriteThrough, store.clone()).unwrap_or_else(|e| panic!("{e}"));
        let baselines: Vec<EvalRequest> =
            (0..suite().len()).map(EvalRequest::baseline).collect();
        let _ = oracle.eval_batch(&baselines);
        drop(oracle);
        assert_eq!(mon.io_error(), None);
    }
    let store_bytes = std::fs::read(&store).unwrap();

    // 2. serve the fleet entirely from the store: coordinator and both
    // workers open it offline — zero live evals, zero misses
    let output = Command::new(exe())
        .args([
            "serve", "--workers", "2", "--tier", "mini", "--seed", "9",
            "--deadline-ms", "180000", "--offline", "--cache",
        ])
        .arg(&store)
        .arg("--out")
        .arg(&out_path)
        .output()
        .expect("run repro serve --cache --offline");
    assert!(
        output.status.success(),
        "serve must exit 0; stderr: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(stdout.contains("shards merged"), "summary line present: {stdout}");
    assert!(
        stdout.contains("0 live, 0 written, 0 miss(es)"),
        "the offline fleet must be fully served by the store: {stdout}"
    );

    // the merged output is byte-identical to the plain single-process
    // evaluation of the same spec and seed
    let bench = Bench::new();
    let work = SuiteWork::single(
        VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
        None,
        9,
        bench.problems.len(),
    );
    let golden = Json::Arr(
        eval_variants(&bench, &work.work, work.seed, 1).iter().map(|l| l.to_json()).collect(),
    )
    .to_string();
    assert_eq!(std::fs::read_to_string(&out_path).unwrap(), golden);

    // single-writer discipline: serving never modified the store
    assert_eq!(std::fs::read(&store).unwrap(), store_bytes);
    let _ = std::fs::remove_file(&store);
    let _ = std::fs::remove_file(&out_path);
}

// ---------------------------------------------------------------------------
// JSONL bridge and maintenance

#[test]
fn export_import_roundtrip_is_lossless_and_byte_identical() {
    let s1 = tmp("rt1.store");
    let trace = tmp("rt.jsonl");
    let s2 = tmp("rt2.store");
    let pairs = sample_pairs();
    build_store(&s1, &pairs);

    let store1 = EvalStore::open(&s1).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(store1.len(), pairs.len(), "sample keys must be distinct");
    let n = export_jsonl(&store1, &trace).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(n as usize, pairs.len());

    // the export replays under the JSONL trace evaluator, bit-identically
    let te = TraceEvaluator::load(&trace).unwrap_or_else(|e| panic!("{e}"));
    let reqs: Vec<EvalRequest> = pairs.iter().map(|(r, _)| r.clone()).collect();
    let served = te.eval_batch(&reqs);
    for ((req, want), got) in pairs.iter().zip(&served) {
        assert_eq!(got, want, "{}", req.key());
        assert_eq!(got.value.to_bits(), want.value.to_bits(), "floats travel bit-identically");
    }

    // and re-imports to a byte-identical store file
    let m = import_jsonl(&trace, &s2).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(m, n);
    assert_eq!(
        std::fs::read(&s2).unwrap(),
        std::fs::read(&s1).unwrap(),
        "store -> JSONL -> store must be the identity on bytes"
    );
    for p in [&s1, &trace, &s2] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn shard_partition_and_merge_reconstruct_the_full_key_set() {
    let full = tmp("part.store");
    let pairs = sample_pairs();
    build_store(&full, &pairs);
    let store = EvalStore::open(&full).unwrap_or_else(|e| panic!("{e}"));

    let of = 3;
    let mut shard_paths = Vec::new();
    let mut union: HashSet<EvalKey> = HashSet::new();
    let mut total = 0u64;
    let mut nonempty = 0;
    for i in 0..of {
        let p = tmp(&format!("part{i}.store"));
        let n = shard_store(&store, i, of, &p).unwrap_or_else(|e| panic!("{e}"));
        total += n;
        let s = EvalStore::open(&p).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(s.len() as u64, n);
        for k in s.keys() {
            assert_eq!(k.shard(of), i, "key {k} must land on its shard");
            assert!(union.insert(k), "shards must be disjoint");
        }
        if !s.is_empty() {
            nonempty += 1;
        }
        shard_paths.push(p);
    }
    assert_eq!(total as usize, store.len());
    assert_eq!(union, store.keys().collect::<HashSet<_>>());
    // 36 content-hash keys over 3 shards: a degenerate split means the
    // partition function is broken, not that we got unlucky
    assert!(nonempty >= 2, "partition must actually split: {nonempty} shard(s) used");
    let err = shard_store(&store, 3, 3, tmp("part_bad.store")).unwrap_err();
    assert!(err.contains("bad shard spec"), "got: {err}");

    // re-merge: same key set, bit-identical responses
    let merged_path = tmp("part_merged.store");
    let opened: Vec<EvalStore> =
        shard_paths.iter().map(|p| EvalStore::open(p).unwrap_or_else(|e| panic!("{e}"))).collect();
    let refs: Vec<&EvalStore> = opened.iter().collect();
    let m = merge_stores(&refs, &merged_path).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(m as usize, store.len());
    let merged = EvalStore::open(&merged_path).unwrap_or_else(|e| panic!("{e}"));
    for (req, want) in &pairs {
        let got = merged
            .get(req.eval_key())
            .unwrap_or_else(|e| panic!("{e}"))
            .expect("merged store serves every key");
        assert_eq!(&got, want);
        assert_eq!(got.value.to_bits(), want.value.to_bits());
    }
    // overlapping identical records dedup rather than duplicate or err
    let again = tmp("part_again.store");
    let re = merge_stores(&[&store, &merged], &again).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(re as usize, store.len());

    // a merged store is already dense: compaction is the identity
    let compacted = tmp("part_compacted.store");
    let (cn, bytes_in, bytes_out) =
        compact_store(&merged, &compacted).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(cn as usize, merged.len());
    assert_eq!(bytes_in, bytes_out);
    assert_eq!(std::fs::read(&compacted).unwrap(), std::fs::read(&merged_path).unwrap());

    for p in shard_paths.iter().chain([&full, &merged_path, &again, &compacted]) {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn merge_refuses_conflicting_records_for_one_key() {
    let a = tmp("conflict_a.store");
    let b = tmp("conflict_b.store");
    let out = tmp("conflict_out.store");
    let req = EvalRequest::baseline(3);
    build_store(&a, &[(req.clone(), EvalResponse::ok(req.eval_key(), 1.0))]);
    build_store(&b, &[(req.clone(), EvalResponse::ok(req.eval_key(), 2.0))]);
    let sa = EvalStore::open(&a).unwrap_or_else(|e| panic!("{e}"));
    let sb = EvalStore::open(&b).unwrap_or_else(|e| panic!("{e}"));
    let err = merge_stores(&[&sa, &sb], &out).unwrap_err();
    assert!(err.contains("conflicting records"), "got: {err}");
    for p in [&a, &b, &out] {
        let _ = std::fs::remove_file(p);
    }
}

// ---------------------------------------------------------------------------
// Hostile-input hardening: the store sits on operator-supplied files, so
// truncated, corrupted, wrong-magic, wrong-version, and duplicate-key
// inputs must come back as in-band errors — never a panic.

#[test]
fn store_open_rejects_corrupt_files_in_band() {
    let path = tmp("neg.store");
    let pairs = sample_pairs();
    build_store(&path, &pairs[..3]);
    let base = std::fs::read(&path).unwrap();
    assert!(EvalStore::open(&path).is_ok(), "baseline store is valid");

    let mangled = tmp("neg_m.store");
    let open_with = |bytes: &[u8]| {
        std::fs::write(&mangled, bytes).unwrap();
        EvalStore::open(&mangled)
    };

    // every truncated prefix fails in-band, never panics
    for cut in (0..base.len()).step_by(13).chain(base.len() - 40..base.len()) {
        assert!(open_with(&base[..cut]).is_err(), "a {cut}-byte prefix must fail in-band");
    }

    // wrong magic: not an eval store
    let mut b = base.clone();
    b[0] ^= 0xff;
    assert!(open_with(&b).err().expect("open must fail").contains("bad magic"));

    // a future format version is rejected, not misread
    let mut b = base.clone();
    b[8..12].copy_from_slice(&(STORE_VERSION + 1).to_le_bytes());
    assert!(open_with(&b).err().expect("open must fail").contains("unsupported store version"));

    // v1 defines no header flags
    let mut b = base.clone();
    b[12] = 1;
    assert!(open_with(&b).err().expect("open must fail").contains("unsupported store flags"));

    let trailer = base.len() - 40;
    // trailer version must agree with the header's
    let mut b = base.clone();
    b[trailer + 8..trailer + 12].copy_from_slice(&(STORE_VERSION + 1).to_le_bytes());
    assert!(open_with(&b).err().expect("open must fail").contains("disagrees"));

    // the trailer's reserved field must be zero
    let mut b = base.clone();
    b[trailer + 12] = 7;
    assert!(open_with(&b).err().expect("open must fail").contains("reserved field"));

    // an index whose checksum does not match is rejected at open
    let index_off =
        u64::from_le_bytes(base[trailer + 24..trailer + 32].try_into().unwrap()) as usize;
    let mut b = base.clone();
    b[index_off + 20] ^= 0x01; // an offset byte inside entry 0
    assert!(open_with(&b).err().expect("open must fail").contains("index checksum mismatch"));

    // a crafted duplicate-key index (checksum recomputed so only the
    // duplicate itself is wrong) is rejected at open
    let mut b = base.clone();
    let key0 = b[index_off..index_off + 16].to_vec();
    b[index_off + 28..index_off + 44].copy_from_slice(&key0);
    let sum = fnv64(&b[index_off..trailer]);
    b[trailer + 32..trailer + 40].copy_from_slice(&sum.to_le_bytes());
    let err = open_with(&b).err().expect("open must fail");
    assert!(err.contains("duplicate key"), "got: {err}");

    // record corruption that open cannot see (payload bytes) is caught
    // by the checksum on the read path
    let mut b = base.clone();
    b[16 + 12] ^= 0x01; // first byte of record 0's payload
    let s = open_with(&b).unwrap_or_else(|e| panic!("structurally intact: {e}"));
    let err = verify_store(&s).unwrap_err();
    assert!(err.contains("checksum mismatch"), "got: {err}");

    for p in [&path, &mangled] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn prop_any_single_byte_flip_is_caught_by_open_or_verify() {
    let path = tmp("flip.store");
    let pairs = sample_pairs();
    build_store(&path, &pairs[..4]);
    let base = std::fs::read(&path).unwrap();
    let mangled = tmp("flip_m.store");
    // every byte of a store is load-bearing: header and trailer fields
    // are all checked at open, the index is checksummed, records must
    // tile the data region exactly, and each record read re-checksums
    // its payload — so any flip fails open() or verify_store()
    prop::check("store-byte-flips", 120, |rng| {
        let mut bytes = base.clone();
        let pos = rng.below(bytes.len());
        bytes[pos] ^= (1 + rng.below(255)) as u8;
        std::fs::write(&mangled, &bytes).unwrap();
        match EvalStore::open(&mangled) {
            Err(_) => {} // caught at open
            Ok(s) => assert!(
                verify_store(&s).is_err(),
                "a flip at byte {pos} of {} must be caught in-band",
                bytes.len()
            ),
        }
    });
    for p in [&path, &mangled] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn writer_discipline_empty_store_dup_keys_and_rejected_appends() {
    // an empty store (header + trailer only) opens and serves nothing
    let empty = tmp("empty.store");
    let mut w = StoreWriter::create(&empty).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(w.len(), 0);
    w.finish().unwrap_or_else(|e| panic!("{e}"));
    w.finish().unwrap_or_else(|e| panic!("finish is idempotent: {e}"));
    let s = EvalStore::open(&empty).unwrap_or_else(|e| panic!("{e}"));
    assert!(s.is_empty());
    assert_eq!(s.file_bytes(), 56);
    assert_eq!(s.open_bytes(), 56);
    assert_eq!(s.get(EvalRequest::baseline(0).eval_key()).unwrap(), None);
    drop(s);

    let path = tmp("writer.store");
    let req = EvalRequest::baseline(5);
    let first = EvalResponse::ok(req.eval_key(), 1.5);
    let mut w = StoreWriter::create(&path).unwrap_or_else(|e| panic!("{e}"));

    // a mismatched (request, response) pair must never land
    let other = EvalRequest::baseline(6);
    let err = w.append(&req, &EvalResponse::ok(other.eval_key(), 1.0)).unwrap_err();
    assert!(err.contains("does not match"), "got: {err}");

    // an oversized record is refused in-band...
    let err = w
        .append(&req, &EvalResponse::error(req.eval_key(), "x".repeat(MAX_RECORD_BYTES)))
        .unwrap_err();
    assert!(err.contains("over the"), "got: {err}");

    // ...and neither rejection poisons the key: the valid record lands
    assert!(w.append(&req, &first).unwrap_or_else(|e| panic!("{e}")));
    // duplicate appends are first-wins, like the JSONL recorder's dedup
    assert!(!w.append(&req, &EvalResponse::ok(req.eval_key(), 9.0)).unwrap());
    drop(w); // no explicit finish: Drop writes the index

    let s = EvalStore::open(&path).unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(s.len(), 1);
    assert_eq!(s.get(req.eval_key()).unwrap().unwrap(), first, "first write wins");
    drop(s);

    // append-after-finish is refused
    let (_s2, mut w2) = StoreWriter::extend(&path).unwrap_or_else(|e| panic!("{e}"));
    w2.finish().unwrap_or_else(|e| panic!("{e}"));
    let err = w2.append(&other, &EvalResponse::ok(other.eval_key(), 2.0)).unwrap_err();
    assert!(err.contains("append after finish"), "got: {err}");
    for p in [&empty, &path] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn offline_miss_is_an_in_band_error_and_read_through_falls_back_live() {
    let path = tmp("miss.store");
    let covered = EvalRequest::baseline(0);
    let live = OwnedAnalytic::new();
    let resp = live.eval(&covered);
    build_store(&path, &[(covered.clone(), resp.clone())]);
    let missing = EvalRequest::sol_gap(1);

    // offline: the covered key serves, the missing one answers in-band
    let cached = CachedEvaluator::open(&path, CacheMode::Offline).unwrap_or_else(|e| panic!("{e}"));
    let mon = cached.monitor();
    let got = cached.eval_batch(&[covered.clone(), missing.clone()]);
    assert_eq!(got[0], resp);
    assert!(!got[1].pass, "a miss is an error response, not a panic");
    assert!(
        got[1].detail.as_deref().unwrap_or("").contains("cache miss:"),
        "names the miss: {:?}",
        got[1].detail
    );
    assert_eq!(mon.hits(), 1);
    assert_eq!(mon.misses(), 1);
    assert_eq!(mon.first_miss(), Some(missing.key()));
    let err = mon.check().unwrap_err();
    assert!(err.contains("not in the store"), "got: {err}");
    assert!(mon.summary().contains("1 miss(es)"), "{}", mon.summary());
    drop(cached);

    // a second touch of a served key is a memory hit, not another pread
    let cached = CachedEvaluator::open(&path, CacheMode::Offline).unwrap_or_else(|e| panic!("{e}"));
    let mon = cached.monitor();
    let _ = cached.eval_batch(&[covered.clone()]);
    let _ = cached.eval_batch(&[covered.clone()]);
    assert_eq!(mon.hits_store(), 1);
    assert_eq!(mon.hits_mem(), 1);
    drop(cached);

    // read-through: the missing key is measured live (a fall-through,
    // not a miss), and the store file is never written
    let before = std::fs::read(&path).unwrap();
    let cached =
        CachedEvaluator::open(&path, CacheMode::ReadThrough(Box::new(OwnedAnalytic::new())))
            .unwrap_or_else(|e| panic!("{e}"));
    let mon = cached.monitor();
    let got = cached.eval_batch(&[covered.clone(), missing.clone()]);
    assert_eq!(got[0], resp);
    assert_eq!(got[1], live.eval(&missing));
    assert_eq!(mon.live(), 1);
    assert_eq!(mon.misses(), 0, "live fall-through is not a miss");
    mon.check().unwrap_or_else(|e| panic!("{e}"));
    drop(cached);
    assert_eq!(std::fs::read(&path).unwrap(), before, "read-through never writes");
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------------
// CLI surface

#[test]
fn cache_cli_stats_export_import_compact_roundtrip() {
    let s1 = tmp("cli1.store");
    let trace = tmp("cli.jsonl");
    let s2 = tmp("cli2.store");
    let s3 = tmp("cli3.store");
    build_store(&s1, &sample_pairs());

    let out = Command::new(exe()).arg("cache").arg("stats").arg(&s1).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("format v1"), "{stdout}");
    assert!(stdout.contains("record(s)"), "{stdout}");
    assert!(stdout.contains("all record checksums verified"), "{stdout}");

    let out = Command::new(exe()).arg("cache").arg("export").arg(&s1).arg(&trace).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let out = Command::new(exe()).arg("cache").arg("import").arg(&trace).arg(&s2).output().unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        std::fs::read(&s2).unwrap(),
        std::fs::read(&s1).unwrap(),
        "CLI export | import must reproduce the store byte-for-byte"
    );

    let out = Command::new(exe())
        .args(["cache", "compact"])
        .arg(&s1)
        .arg("--out")
        .arg(&s3)
        .output()
        .unwrap();
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        std::fs::read(&s3).unwrap(),
        std::fs::read(&s1).unwrap(),
        "a dense store compacts to itself"
    );

    // error paths: missing file, missing --out, unknown subcommand
    let out = Command::new(exe()).args(["cache", "stats", "no_such.store"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error:"));
    let out = Command::new(exe()).args(["cache", "compact"]).arg(&s1).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--out"));
    let out = Command::new(exe()).args(["cache", "bogus"]).output().unwrap();
    assert!(!out.status.success());

    for p in [&s1, &trace, &s2, &s3] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn cache_flag_validation_rejects_misuse_before_running_anything() {
    let check = |args: &[&str], needle: &str| {
        let out = Command::new(exe()).args(args).output().expect("run repro");
        assert!(!out.status.success(), "{args:?} must exit nonzero");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{args:?}: expected `{needle}` in: {stderr}");
    };
    // --cache is scoped to the subcommands that evaluate
    check(&["sol", "--cache", "x.store"], "--cache is only meaningful");
    // a bare --cache parses as the flag sentinel, not a file named `true`
    check(&["run", "--tier", "mini", "--cache"], "needs a file path");
    // one oracle at a time (`sweep` is the one subcommand where both
    // flags are in scope); the bridge is `repro cache export|import`
    check(
        &["sweep", "--cache", "a.store", "--trace", "b.jsonl"],
        "mutually exclusive",
    );
    check(&["run", "--tier", "mini", "--offline"], "--offline needs --cache");
    // serve fails fast, coordinator-side, before any worker spawns
    check(
        &["serve", "--workers", "2", "--offline", "--cache", "no_such_dir/no_such.store"],
        "error: store",
    );
}
