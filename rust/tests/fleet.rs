//! Fleet end-to-end tests (ADR-007 acceptance): real `repro worker`
//! subprocesses driven by the coordinator — and the `repro serve` CLI —
//! must converge to output field-for-field identical to a single-process
//! `eval_variants`, under scripted faults included, and must fail in-band
//! (nonzero exit, `error:` on stderr) when every worker dies.

use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::eval::manifest::SuiteWork;
use ucutlass_repro::exec::eval_variants;
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::fleet::{
    run_fleet, subprocess_worker_factory, EventLog, FleetConfig, FleetError,
};
use ucutlass_repro::mantis::MantisConfig;
use ucutlass_repro::util::json::Json;

fn exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("repro_fleet_{}_{name}", std::process::id()))
}

fn golden_json(bench: &Bench, work: &SuiteWork) -> String {
    let logs = eval_variants(bench, &work.work, work.seed, 1);
    Json::Arr(logs.iter().map(|l| l.to_json()).collect()).to_string()
}

/// Generous deadlines: debug builds compute shards slowly, and a spurious
/// timeout would make these tests racy. Fault-timing behavior is pinned by
/// the in-process unit tests; here the subject is the subprocess path.
fn cfg(workers: usize) -> FleetConfig {
    FleetConfig {
        workers,
        deadline: Duration::from_secs(180),
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(50),
        ..FleetConfig::default()
    }
}

/// One flat variant (fans per problem) + one sequentially-coupled
/// orchestrated variant (cross-memory on → a single whole-variant task),
/// mirroring the shard/merge golden job shape.
fn mixed_work(bench: &Bench) -> SuiteWork {
    SuiteWork {
        seed: 77,
        problems: bench.problems.len(),
        work: vec![
            (VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini), None),
            (
                VariantSpec::new(ControllerKind::OrchestratedSol, false, ModelTier::Mini),
                Some(MantisConfig::default()),
            ),
        ],
    }
}

#[test]
fn subprocess_fleet_matches_single_process_run() {
    let bench = Bench::new();
    let work = mixed_work(&bench);
    let events = EventLog::new();
    let out = run_fleet(
        &bench,
        &work,
        &cfg(2),
        subprocess_worker_factory(exe(), vec![String::new(); 2], Vec::new()),
        &events,
    )
    .unwrap_or_else(|e| panic!("faultless subprocess fleet must converge: {e}"));
    let got = Json::Arr(out.logs.iter().map(|l| l.to_json()).collect()).to_string();
    assert_eq!(got, golden_json(&bench, &work), "byte-identical to one process");
    assert_eq!(out.stats.retries, 0);
    assert_eq!(events.count("merge"), out.stats.shards);
}

#[test]
fn subprocess_fleet_converges_through_worker_crashes() {
    let bench = Bench::new();
    let work = SuiteWork::single(
        VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
        None,
        41,
        bench.problems.len(),
    );
    let events = EventLog::new();
    // slot 0 crashes on its first and third assignments; the respawned
    // processes resume the plan via --fault-offset
    let out = run_fleet(
        &bench,
        &work,
        &cfg(2),
        subprocess_worker_factory(exe(), vec!["0:crash,2:crash".into(), String::new()], Vec::new()),
        &events,
    )
    .unwrap_or_else(|e| panic!("fleet must converge through crashes: {e}"));
    let got = Json::Arr(out.logs.iter().map(|l| l.to_json()).collect()).to_string();
    assert_eq!(got, golden_json(&bench, &work));
    assert!(out.stats.respawns >= 2, "each crash respawns: {:?}", out.stats);
    assert!(events.count("respawn") >= 2);
}

#[test]
fn serve_cli_end_to_end_with_crash_recovery() {
    let out_path = tmp("serve_out.json");
    let events_path = tmp("serve_events.jsonl");
    let output = Command::new(exe())
        .args([
            "serve", "--workers", "2", "--tier", "mini", "--seed", "9",
            "--deadline-ms", "180000", "--faults", "0=0:crash",
        ])
        .arg("--out")
        .arg(&out_path)
        .arg("--events")
        .arg(&events_path)
        .output()
        .expect("run repro serve");
    assert!(
        output.status.success(),
        "serve must exit 0; stderr: {}",
        String::from_utf8_lossy(&output.stderr)
    );
    let stdout = String::from_utf8_lossy(&output.stdout);
    assert!(stdout.contains("shards merged"), "summary line present: {stdout}");

    // merged logs are byte-identical to the single-process evaluation of
    // the same spec and seed
    let bench = Bench::new();
    let work = SuiteWork::single(
        VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
        None,
        9,
        bench.problems.len(),
    );
    let got = std::fs::read_to_string(&out_path).expect("serve wrote --out");
    assert_eq!(got, golden_json(&bench, &work), "CLI output matches single-process run");

    // the event log is JSONL with assign/merge/respawn records
    let ev = std::fs::read_to_string(&events_path).expect("serve wrote --events");
    let mut kinds = std::collections::HashSet::new();
    for line in ev.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("event not JSON: {e}: {line}"));
        assert!(j.get("t_ms").is_some());
        kinds.insert(j.get("event").and_then(|k| k.as_str()).expect("event kind").to_string());
    }
    for want in ["spawn", "ready", "assign", "merge", "crash", "respawn", "done"] {
        assert!(kinds.contains(want), "event log must record `{want}`; got {kinds:?}");
    }
    let _ = std::fs::remove_file(&out_path);
    let _ = std::fs::remove_file(&events_path);
}

#[test]
fn serve_cli_all_workers_dead_exits_nonzero_in_band() {
    // one worker, quarantined on its first crash: nobody left to run the
    // job — must exit nonzero with an in-band error, not panic or hang
    let output = Command::new(exe())
        .args([
            "serve", "--workers", "1", "--tier", "mini", "--quarantine-after", "1",
            "--deadline-ms", "180000", "--faults", "0=0:crash",
        ])
        .output()
        .expect("run repro serve");
    assert!(!output.status.success(), "all-dead must exit nonzero");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("error:"), "in-band error on stderr: {stderr}");
    assert!(
        stderr.contains("workers dead or quarantined"),
        "names the failure mode: {stderr}"
    );
}

#[test]
fn fleet_error_display_names_every_failure_mode() {
    // in-band error surface the CLI prints; pinned so messages stay useful
    let cases = [
        (FleetError::Spawn("no exe".into()), "spawning worker"),
        (
            FleetError::RetriesExhausted { shard: 3, failures: 4, last: "deadline".into() },
            "shard 3 exhausted",
        ),
        (FleetError::AllWorkersDead { completed: 2, total: 9 }, "2/9 shards merged"),
        (FleetError::Merge("duplicate task".into()), "merging shards"),
        (FleetError::Internal("oops".into()), "coordinator"),
    ];
    for (err, needle) in cases {
        let msg = err.to_string();
        assert!(msg.contains(needle), "`{msg}` should contain `{needle}`");
    }
}
