//! Shard/merge golden tests (ADR-003 acceptance): splitting a suite
//! evaluation across N workers and merging their JSON shards must be
//! field-for-field identical to the single-process `eval_variants` result,
//! and every evaluator's batched path must agree with its scalar path.

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::{ModelTier, RunLog};
use ucutlass_repro::dsl::DType;
use ucutlass_repro::eval::manifest::{
    evaluate_shard, suite_merge, suite_shard, ResponseShard, SuiteShard, SuiteWork,
    MANIFEST_VERSION, MAX_ARTIFACT_BYTES,
};
use ucutlass_repro::eval::{
    AnalyticEvaluator, EvalRequest, Evaluator, ManifestEvaluator, PjrtEvaluator, WorkManifest,
};
use ucutlass_repro::exec;
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::mantis::MantisConfig;
use ucutlass_repro::perfmodel::CandidateConfig;
use ucutlass_repro::util::json::Json;
use ucutlass_repro::util::prop;
use ucutlass_repro::util::rng::{stream, Pcg32, StreamPath};

fn job() -> (Bench, SuiteWork) {
    let bench = Bench::new();
    // one flat variant (fans out per problem) + one orchestrated default
    // (cross-memory on → a single whole-variant task, as in ADR-002)
    let work = SuiteWork {
        seed: 2024,
        problems: bench.problems.len(),
        work: vec![
            (VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid), None),
            (
                VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini),
                Some(MantisConfig::default()),
            ),
        ],
    };
    (bench, work)
}

#[test]
fn shard_merge_golden_matches_single_process_eval_variants() {
    let (bench, job) = job();
    let reference: Vec<RunLog> = exec::eval_variants(&bench, &job.work, job.seed, 1);

    for n in [1usize, 3] {
        // every shard goes through its JSON text form, exactly as the
        // repro shard / repro merge CLI round-trips it between processes
        let shards: Vec<SuiteShard> = (0..n)
            .map(|i| {
                let s = suite_shard(&bench, &job, i, n);
                SuiteShard::parse(&s.to_json().to_string()).unwrap_or_else(|e| panic!("{e}"))
            })
            .collect();
        let merged = suite_merge(&shards).unwrap_or_else(|e| panic!("{e}"));
        assert_eq!(
            merged, reference,
            "{n}-way shard + merge must be field-for-field identical to one process"
        );
        // and byte-identical as persisted artifacts
        for (m, r) in merged.iter().zip(&reference) {
            assert_eq!(m.to_json().to_string(), r.to_json().to_string());
        }
    }
}

#[test]
fn shard_merge_rejects_incomplete_shard_sets() {
    let (bench, job) = job();
    let s0 = suite_shard(&bench, &job, 0, 2);
    let err = suite_merge(&[s0]).unwrap_err();
    assert!(err.contains("missing task"), "got: {err}");
}

#[test]
fn shard_merge_runlog_json_roundtrip_is_exact() {
    // the serialization the protocol rests on: a full run log (plans,
    // configs, floats) survives JSON round-trip PartialEq-identical
    let bench = Bench::new();
    let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mid);
    let log = exec::run_variant_jobs(&bench, &spec, 7, None, 1);
    let text = log.to_json().to_string();
    let mut plans = ucutlass_repro::dsl::PlanCache::new();
    let parsed = RunLog::from_json(
        &ucutlass_repro::util::json::Json::parse(&text).unwrap(),
        &mut plans,
    )
    .unwrap_or_else(|e| panic!("{e}"));
    assert_eq!(parsed, log);
    assert_eq!(parsed.to_json().to_string(), text, "serialization is a fixed point");
}

// ---------------------------------------------------------------------------
// Hostile-input hardening (ADR-007 satellite): the shard parsers sit on the
// fleet wire and on operator-supplied artifact files, so truncated,
// corrupted, overlong, wrong-version, and duplicate-key inputs must come
// back as in-band errors — never a panic, never a silently skewed merge.

/// A cheap valid suite-shard artifact: with `of` = task count, shard 0
/// evaluates exactly one problem.
fn small_shard_text() -> String {
    let bench = Bench::new();
    let work = SuiteWork::single(
        VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
        None,
        5,
        bench.problems.len(),
    );
    let of = exec::suite_tasks(&work.work, work.problems).len();
    suite_shard(&bench, &work, 0, of).to_json().to_string()
}

/// A small valid response-shard artifact.
fn small_response_text(bench: &Bench) -> String {
    let analytic =
        AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
    let manifest = WorkManifest::new(vec![EvalRequest::baseline(0), EvalRequest::sol_gap(1)]);
    evaluate_shard(&analytic, &manifest, 0, 1).to_json().to_string()
}

/// Re-serialize an artifact with its top-level object fields altered.
fn mutated(text: &str, f: impl FnOnce(&mut std::collections::BTreeMap<String, Json>)) -> String {
    let mut j = Json::parse(text).unwrap();
    match &mut j {
        Json::Obj(m) => f(m),
        _ => panic!("artifact must be a JSON object"),
    }
    j.to_string()
}

#[test]
fn suite_shard_parse_rejects_corrupt_artifacts_in_band() {
    let text = small_shard_text();
    assert!(SuiteShard::parse(&text).is_ok(), "baseline artifact is valid");

    // every truncated prefix is a parse error, never a panic (compact
    // output has no trailing whitespace, so no strict prefix is valid)
    for cut in (0..text.len()).step_by(13).chain(text.len().saturating_sub(40)..text.len()) {
        if !text.is_char_boundary(cut) {
            continue;
        }
        assert!(
            SuiteShard::parse(&text[..cut]).is_err(),
            "a {cut}-byte prefix must fail in-band"
        );
    }

    // shape gates
    let bad = mutated(&text, |m| {
        m.insert("of".into(), Json::Num(0.0));
    });
    assert!(SuiteShard::parse(&bad).unwrap_err().contains("of must be >= 1"));
    let bad = mutated(&text, |m| {
        m.insert("index".into(), Json::Num(9.0));
        m.insert("of".into(), Json::Num(9.0));
    });
    assert!(SuiteShard::parse(&bad).unwrap_err().contains("out of range"));

    // version gates: a future build and a pre-version artifact (= v1)
    // are both rejected loudly — a mixed-version fleet must not merge
    let bad = mutated(&text, |m| {
        m.insert("version".into(), Json::Num((MANIFEST_VERSION + 1) as f64));
    });
    assert!(SuiteShard::parse(&bad).unwrap_err().contains("unsupported version"));
    let bad = mutated(&text, |m| {
        m.remove("version");
    });
    assert!(SuiteShard::parse(&bad).unwrap_err().contains("unsupported version 1"));

    // a duplicated task result must not get the chance to merge twice
    let bad = mutated(&text, |m| {
        if let Some(Json::Arr(rs)) = m.get_mut("results") {
            let first = rs[0].clone();
            rs.push(first);
        }
    });
    assert!(SuiteShard::parse(&bad).unwrap_err().contains("duplicate task"));
}

#[test]
fn response_shard_parse_rejects_corrupt_artifacts_in_band() {
    let bench = Bench::new();
    let text = small_response_text(&bench);
    assert!(ResponseShard::parse(&text).is_ok(), "baseline artifact is valid");

    for cut in 0..text.len() {
        assert!(ResponseShard::parse(&text[..cut]).is_err(), "{cut}-byte prefix");
    }

    let bad = mutated(&text, |m| {
        m.insert("of".into(), Json::Num(0.0));
    });
    assert!(ResponseShard::parse(&bad).unwrap_err().contains("of must be >= 1"));
    let bad = mutated(&text, |m| {
        m.insert("index".into(), Json::Num(4.0));
    });
    assert!(ResponseShard::parse(&bad).unwrap_err().contains("out of range"));
    let bad = mutated(&text, |m| {
        m.insert("version".into(), Json::Num((MANIFEST_VERSION + 1) as f64));
    });
    assert!(ResponseShard::parse(&bad).unwrap_err().contains("unsupported version"));
    let bad = mutated(&text, |m| {
        m.remove("version");
    });
    assert!(ResponseShard::parse(&bad).unwrap_err().contains("unsupported version 1"));
    let bad = mutated(&text, |m| {
        if let Some(Json::Arr(rs)) = m.get_mut("responses") {
            let first = rs[0].clone();
            rs.push(first);
        }
    });
    assert!(ResponseShard::parse(&bad).unwrap_err().contains("duplicate response key"));
}

#[test]
fn prop_shard_parsers_never_panic_on_byte_flips() {
    let bench = Bench::new();
    let suite_text = small_shard_text();
    let resp_text = small_response_text(&bench);
    prop::check("shard-parse-byte-flips", 120, |rng| {
        for base in [&suite_text, &resp_text] {
            let mut bytes = base.clone().into_bytes();
            for _ in 0..1 + rng.below(3) {
                let pos = rng.below(bytes.len());
                bytes[pos] = b' ' + rng.below(95) as u8; // printable ASCII
            }
            if let Ok(s) = String::from_utf8(bytes) {
                // the outcome may be Ok (flip landed inside string
                // content) or an in-band Err; the property is "no panic"
                let _ = SuiteShard::parse(&s);
                let _ = ResponseShard::parse(&s);
            }
        }
    });
}

#[test]
fn overlong_artifacts_are_rejected_before_parsing() {
    // one byte over the cap: every parse entry point refuses in-band
    // without attempting a 64 MiB JSON parse
    let big = "x".repeat(MAX_ARTIFACT_BYTES + 1);
    for err in [
        SuiteShard::parse(&big).unwrap_err(),
        ResponseShard::parse(&big).unwrap_err(),
        WorkManifest::parse(&big).unwrap_err(),
    ] {
        assert!(err.contains("over the"), "got: {err}");
    }
}

/// Random request generator for the batch≡scalar property.
fn random_requests(rng: &mut Pcg32, n_problems: usize) -> Vec<EvalRequest> {
    let tiles = ucutlass_repro::agent::policy::TILES;
    (0..1 + rng.below(24))
        .map(|i| {
            let p = rng.below(n_problems);
            let cfg = CandidateConfig::library(
                *rng.choice(tiles),
                *rng.choice(&[DType::Fp32, DType::Fp16, DType::Bf16]),
            );
            let at = StreamPath::new(
                rng.next_u64(),
                &[stream::MEASURE, stream::PROP_CASE, p as u64, i as u64],
            );
            match rng.below(5) {
                0 => EvalRequest::baseline(p),
                1 => EvalRequest::measured_baseline(p, at),
                2 => EvalRequest::candidate(p, cfg),
                3 => EvalRequest::measured(p, cfg, at),
                _ => EvalRequest::sol_gap(p),
            }
        })
        .collect()
}

#[test]
fn prop_eval_batch_equals_mapped_scalar_for_all_evaluators() {
    let bench = Bench::new();
    let analytic =
        AnalyticEvaluator::new(&bench.model, &bench.problems, &bench.sols, &bench.compiled);
    let pjrt = PjrtEvaluator::open("artifacts", bench.problems.clone());
    prop::check("eval-batch-vs-scalar", 40, |rng| {
        let reqs = random_requests(rng, bench.problems.len());

        let batch = analytic.eval_batch(&reqs);
        for (r, b) in reqs.iter().zip(&batch) {
            assert_eq!(*b, analytic.eval(r), "analytic: {}", r.key());
        }

        let batch = pjrt.eval_batch(&reqs);
        for (r, b) in reqs.iter().zip(&batch) {
            assert_eq!(*b, pjrt.eval(r), "pjrt: {}", r.key());
        }

        // manifest evaluator, in both phases: collecting and serving
        let collector = ManifestEvaluator::new();
        let pending = collector.eval_batch(&reqs);
        for (r, b) in reqs.iter().zip(&pending) {
            assert_eq!(*b, collector.eval(r), "manifest(pending): {}", r.key());
        }
        let manifest = WorkManifest::new(reqs.clone());
        let shard = ucutlass_repro::eval::manifest::evaluate_shard(&analytic, &manifest, 0, 1);
        let served = ManifestEvaluator::with_responses(&manifest, &[shard]).unwrap();
        let batch = served.eval_batch(&reqs);
        for (r, b) in reqs.iter().zip(&batch) {
            assert_eq!(*b, served.eval(r), "manifest(served): {}", r.key());
        }
        // and the served answers are the analytic answers
        assert_eq!(batch, analytic.eval_batch(&reqs));
    });
}
