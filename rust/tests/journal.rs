//! Crash-safety end-to-end tests (ADR-010 acceptance): a `kill -9` at
//! any journal frame boundary — or inside a frame — must resume to
//! output byte-identical to the uninterrupted run with zero landed keys
//! re-measured; a store torn mid-append/mid-finish must refuse to open
//! in-band while `repair` recovers exactly the checksummed-valid record
//! prefix; GC must be the identity under budget and evict strictly
//! least-recently-served over it; orphaned workers must exit on a stale
//! coordinator lease; and the `repro` CLI must wire all of it.

use std::collections::HashSet;
use std::io::Read;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::policy::TILES;
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::dsl::DType;
use ucutlass_repro::eval::manifest::SuiteWork;
use ucutlass_repro::eval::{EvalKey, EvalRequest, EvalResponse, Evaluator, OwnedAnalytic};
use ucutlass_repro::exec::eval_variants;
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::fleet::{
    parse_events_jsonl, run_fleet_journaled, thread_worker_factory, EventLog, FaultPlan,
    FleetConfig, FleetOutcome,
};
use ucutlass_repro::journal::{scan_journal, RunJournal, Tail, JOURNAL_HEADER_BYTES};
use ucutlass_repro::perfmodel::CandidateConfig;
use ucutlass_repro::store::{
    cache_session, compact_store, gc_store, lru_sidecar_path, read_lru_sidecar, repair_store,
    verify_store, CacheSessionMode, EvalStore, StoreWriter,
};
use ucutlass_repro::util::json::Json;
use ucutlass_repro::util::rng::{stream, StreamPath};

fn exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_repro"))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("ucutlass_journal_{}_{name}", std::process::id()))
}

/// Generous deadline (debug builds are slow), tight backoff: retries are
/// instant, spurious timeouts are impossible.
fn fast_cfg(workers: usize, shards: usize) -> FleetConfig {
    FleetConfig {
        workers,
        shards,
        deadline: Duration::from_secs(180),
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(20),
        ..FleetConfig::default()
    }
}

fn mini_work(bench: &Bench, seed: u64) -> SuiteWork {
    SuiteWork::single(
        VariantSpec::new(ControllerKind::Mi, false, ModelTier::Mini),
        None,
        seed,
        bench.problems.len(),
    )
}

fn golden_json(bench: &Bench, work: &SuiteWork) -> String {
    let logs = eval_variants(bench, &work.work, work.seed, 1);
    Json::Arr(logs.iter().map(|l| l.to_json()).collect()).to_string()
}

fn fleet_json(out: &FleetOutcome) -> String {
    Json::Arr(out.logs.iter().map(|l| l.to_json()).collect()).to_string()
}

fn kind_count(records: &[Json], kind: &str) -> usize {
    records
        .iter()
        .filter(|r| r.get("kind").and_then(|k| k.as_str()) == Some(kind))
        .count()
}

// ---------------------------------------------------------------------------
// The tentpole property: kill at every journal boundary, resume, compare

#[test]
fn fleet_resume_is_byte_identical_after_a_kill_at_every_journal_boundary() {
    let bench = Arc::new(Bench::new());
    let work = mini_work(&bench, 31);
    let cfg = fast_cfg(2, 4);
    let golden = golden_json(&bench, &work);

    // the uninterrupted journaled run: its output is the golden, and its
    // journal is the file we then "kill" at every boundary of
    let p = tmp("boundary.journal");
    let _ = std::fs::remove_file(&p);
    {
        let j = RunJournal::create(&p).unwrap();
        let events = EventLog::new();
        let out = run_fleet_journaled(
            &bench,
            &work,
            &cfg,
            thread_worker_factory(Arc::clone(&bench), Vec::new()),
            &events,
            Some(&j),
        )
        .unwrap_or_else(|e| panic!("journaled run must converge: {e}"));
        assert_eq!(fleet_json(&out), golden, "journaling must not change the output");
        assert_eq!(out.stats.recovered, 0, "a fresh journal recovers nothing");
    }
    let full = std::fs::read(&p).unwrap();
    let scan = scan_journal(&p).unwrap();
    assert_eq!(scan.tail, Tail::Clean);
    assert_eq!(kind_count(&scan.records, "shard"), 4, "one record per landed shard");
    assert_eq!(kind_count(&scan.records, "done"), 1);

    // kill points: before the start record committed, after every frame,
    // and inside a frame (a genuinely torn tail) for the first two frames
    let mut cuts: Vec<u64> = vec![JOURNAL_HEADER_BYTES];
    cuts.extend(scan.ends.iter().copied());
    for k in 0..2usize.min(scan.ends.len()) {
        cuts.push(scan.ends[k] - 3);
    }

    for cut in cuts {
        let pk = tmp(&format!("boundary_cut_{cut}.journal"));
        std::fs::write(&pk, &full[..cut as usize]).unwrap();
        let pre = scan_journal(&pk).unwrap_or_else(|e| panic!("cut {cut} prefix scans: {e}"));
        let landed = kind_count(&pre.records, "shard");
        let was_done = kind_count(&pre.records, "done") == 1;
        match RunJournal::resume(&pk) {
            Err(e) => {
                // only a journal killed before its start record committed
                // refuses — and in-band, telling the user what to do
                assert!(pre.records.is_empty(), "cut {cut}: unexpected refusal: {e}");
                assert!(e.contains("no start record"), "cut {cut}: {e}");
            }
            Ok(j) => {
                let events = EventLog::new();
                let out = run_fleet_journaled(
                    &bench,
                    &work,
                    &cfg,
                    thread_worker_factory(Arc::clone(&bench), Vec::new()),
                    &events,
                    Some(&j),
                )
                .unwrap_or_else(|e| panic!("resume at cut {cut} must converge: {e}"));
                assert_eq!(fleet_json(&out), golden, "cut {cut}: byte-identical resume");
                // zero landed keys re-measured: every journaled shard is
                // replayed (never assigned), only the rest merge live
                assert_eq!(out.stats.recovered, landed, "cut {cut}");
                assert_eq!(events.count("recovered"), landed, "cut {cut}");
                assert_eq!(events.count("merge"), out.stats.shards - landed, "cut {cut}");
                if was_done {
                    assert_eq!(out.stats.assigns, 0, "cut {cut}: done journal spawns no work");
                }
            }
        }
        let _ = std::fs::remove_file(&pk);
    }
    let _ = std::fs::remove_file(&p);
}

#[test]
fn fleet_resume_under_scripted_faults_is_byte_identical() {
    let bench = Arc::new(Bench::new());
    let work = mini_work(&bench, 47);
    let cfg = fast_cfg(2, 4);
    let golden = golden_json(&bench, &work);
    let plans = || {
        vec![FaultPlan::parse("0:crash,2:garbage").unwrap(), FaultPlan::default()]
    };

    // journal a full run whose workers crash and corrupt mid-flight
    let p = tmp("faulty.journal");
    let _ = std::fs::remove_file(&p);
    {
        let j = RunJournal::create(&p).unwrap();
        let events = EventLog::new();
        let out = run_fleet_journaled(
            &bench,
            &work,
            &cfg,
            thread_worker_factory(Arc::clone(&bench), plans()),
            &events,
            Some(&j),
        )
        .unwrap_or_else(|e| panic!("faulty journaled run must converge: {e}"));
        assert_eq!(fleet_json(&out), golden);
    }
    // kill the coordinator mid-run (truncate to a boundary with some but
    // not all shards landed) and resume under the SAME fault script
    let scan = scan_journal(&p).unwrap();
    let cut = scan.ends[scan.ends.len() / 2];
    let full = std::fs::read(&p).unwrap();
    std::fs::write(&p, &full[..cut as usize]).unwrap();
    let landed = kind_count(&scan_journal(&p).unwrap().records, "shard");
    let j = RunJournal::resume(&p).unwrap();
    let events = EventLog::new();
    let out = run_fleet_journaled(
        &bench,
        &work,
        &cfg,
        thread_worker_factory(Arc::clone(&bench), plans()),
        &events,
        Some(&j),
    )
    .unwrap_or_else(|e| panic!("faulty resume must converge: {e}"));
    assert_eq!(fleet_json(&out), golden, "faults + mid-run kill still converge");
    assert_eq!(out.stats.recovered, landed);
    let _ = std::fs::remove_file(&p);
}

// ---------------------------------------------------------------------------
// Store crash window: open refuses in-band, repair recovers the valid prefix

/// Deterministic distinct request/response pairs (subset of the ADR-008
/// sample set: every key distinct, every MeasureKind covered).
fn sample_pairs(n: usize) -> Vec<(EvalRequest, EvalResponse)> {
    let dtypes = [DType::Fp32, DType::Fp16, DType::Bf16];
    let reqs: Vec<EvalRequest> = (0..n)
        .map(|i| {
            let p = i % 7;
            let cfg = CandidateConfig::library(TILES[i % TILES.len()], dtypes[i % 3]);
            let at =
                StreamPath::new(42, &[stream::MEASURE, stream::PROP_CASE, p as u64, i as u64]);
            match i % 5 {
                0 => EvalRequest::baseline(p),
                1 => EvalRequest::measured_baseline(p, at),
                2 => EvalRequest::candidate(p, cfg),
                3 => EvalRequest::measured(p, cfg, at),
                _ => EvalRequest::sol_gap(p),
            }
        })
        .collect();
    let live = OwnedAnalytic::new();
    let resps = live.eval_batch(&reqs);
    reqs.into_iter().zip(resps).collect()
}

fn build_store(path: &PathBuf, pairs: &[(EvalRequest, EvalResponse)]) {
    let _ = std::fs::remove_file(path);
    let mut w = StoreWriter::create(path).unwrap_or_else(|e| panic!("{e}"));
    for (req, resp) in pairs {
        assert!(w.append(req, resp).unwrap_or_else(|e| panic!("{e}")));
    }
    w.finish().unwrap_or_else(|e| panic!("{e}"));
}

#[test]
fn store_truncated_anywhere_fails_open_in_band_and_repair_recovers_the_valid_prefix() {
    let pairs = sample_pairs(5);
    let p = tmp("crashwin.store");
    build_store(&p, &pairs);
    let full = std::fs::read(&p).unwrap();
    let keys: Vec<EvalKey> = EvalStore::open(&p).unwrap().keys().collect();

    let rdst = tmp("crashwin_rep.store");
    let full_rep = repair_store(&p, &rdst).unwrap();
    assert_eq!(full_rep.records, pairs.len() as u64, "intact store repairs whole");
    // on a finished store the record scan stops at the index region —
    // which repair rebuilds fresh, so those dropped bytes lose nothing
    assert!(full_rep.stopped.is_some());
    assert!(full_rep.dropped_bytes > 0);
    let data_end = full.len() as u64 - full_rep.dropped_bytes;

    // enumerate the whole crash window byte by byte: through the record
    // appends, into the index write, and through the trailer
    let trunc = tmp("crashwin_cut.store");
    let mut prev = 0u64;
    for cut in 0..full.len() {
        std::fs::write(&trunc, &full[..cut]).unwrap();
        assert!(
            EvalStore::open(&trunc).is_err(),
            "cut {cut}: a torn store must never open (in-band refusal)"
        );
        match repair_store(&trunc, &rdst) {
            Err(e) => {
                assert!(cut < 16, "cut {cut}: only sub-header prefixes are unrepairable: {e}");
                assert!(e.contains("truncated") || e.contains("header"), "cut {cut}: {e}");
            }
            Ok(rep) => {
                assert!(cut >= 16);
                let k = rep.records;
                assert!(k >= prev, "cut {cut}: recovered count is monotone in prefix length");
                assert!(k <= pairs.len() as u64);
                if cut as u64 >= data_end {
                    assert_eq!(k, pairs.len() as u64, "cut {cut}: all records precede the index");
                }
                prev = k;
                let store = EvalStore::open(&rdst)
                    .unwrap_or_else(|e| panic!("cut {cut}: repaired store must open: {e}"));
                // exactly the checksummed-valid prefix, in append order,
                // every byte re-verified
                let got: Vec<EvalKey> = store.keys().collect();
                assert_eq!(got, keys[..k as usize], "cut {cut}");
                verify_store(&store).unwrap_or_else(|e| panic!("cut {cut}: {e}"));
            }
        }
    }
    assert_eq!(prev, pairs.len() as u64, "the crash window sweep reached a full recovery");
    for f in [&p, &rdst, &trunc] {
        let _ = std::fs::remove_file(f);
    }
}

#[test]
fn repair_of_an_intact_store_is_byte_identical_to_compaction() {
    let pairs = sample_pairs(6);
    let p = tmp("repair_eq.store");
    build_store(&p, &pairs);
    let c = tmp("repair_eq_c.store");
    let r = tmp("repair_eq_r.store");
    compact_store(&EvalStore::open(&p).unwrap(), &c).unwrap();
    repair_store(&p, &r).unwrap();
    assert_eq!(
        std::fs::read(&c).unwrap(),
        std::fs::read(&r).unwrap(),
        "repair on an intact store IS compaction"
    );
    for f in [&p, &c, &r] {
        let _ = std::fs::remove_file(f);
    }
}

// ---------------------------------------------------------------------------
// GC: identity under budget, least-recently-served eviction over it

#[test]
fn gc_is_the_identity_under_budget_and_evicts_least_recently_served_over_it() {
    let pairs = sample_pairs(8);
    let p = tmp("gc.store");
    build_store(&p, &pairs);
    let store = EvalStore::open(&p).unwrap();
    let keys: Vec<EvalKey> = store.keys().collect();

    // under budget: byte-for-byte the compaction (identity rewrite)
    let g1 = tmp("gc_id.store");
    let c1 = tmp("gc_id_c.store");
    let rep = gc_store(&store, u64::MAX, &g1, &[], &HashSet::new()).unwrap();
    assert_eq!(rep.evicted, 0);
    assert_eq!(rep.kept, keys.len() as u64);
    compact_store(&store, &c1).unwrap();
    assert_eq!(std::fs::read(&g1).unwrap(), std::fs::read(&c1).unwrap());

    // recency: keys[3] was served, then keys[1] (hottest). Coldness is
    // never-served first (append order), then by last-served position.
    let recency = vec![keys[3], keys[1]];
    let cold: Vec<EvalKey> = keys
        .iter()
        .copied()
        .filter(|k| *k != keys[3] && *k != keys[1])
        .chain([keys[3], keys[1]])
        .collect();
    let bytes_full = std::fs::metadata(&g1).unwrap().len();
    let g2 = tmp("gc_evict.store");
    let rep = gc_store(&store, bytes_full - 1, &g2, &recency, &HashSet::new()).unwrap();
    assert!(rep.evicted >= 1, "one byte over budget evicts at least one record");
    assert_eq!(rep.kept + rep.evicted, keys.len() as u64);
    assert!(rep.bytes_out <= bytes_full - 1, "the rewrite fits the budget");
    // exactly the coldest `evicted` keys go; survivors keep append order
    let survivors: HashSet<EvalKey> = cold[rep.evicted as usize..].iter().copied().collect();
    let got = EvalStore::open(&g2).unwrap();
    let got_keys: Vec<EvalKey> = got.keys().collect();
    let expect: Vec<EvalKey> =
        keys.iter().copied().filter(|k| survivors.contains(k)).collect();
    assert_eq!(got_keys, expect, "evicts least-recently-served, preserves append order");
    verify_store(&got).unwrap();

    // a budget below the pinned keys' floor is an in-band error
    let err =
        gc_store(&store, 100, &g2, &recency, &HashSet::from([keys[0]])).unwrap_err();
    assert!(err.contains("pinned"), "{err}");
    for f in [&p, &g1, &c1, &g2] {
        let _ = std::fs::remove_file(f);
    }
}

#[test]
fn cached_sessions_append_the_lru_sidecar_gc_ranks_by() {
    let p = tmp("lru.store");
    let side = lru_sidecar_path(&p);
    let _ = std::fs::remove_file(&p);
    let _ = std::fs::remove_file(&side);
    let pairs = sample_pairs(6);
    let reqs: Vec<EvalRequest> = pairs.iter().map(|(r, _)| r.clone()).collect();
    let want: Vec<EvalKey> = reqs.iter().map(|r| r.eval_key()).collect();
    {
        let (oracle, _mon) = cache_session(CacheSessionMode::WriteThrough, p.clone()).unwrap();
        let _ = oracle.eval_batch(&reqs);
        // drop finishes the store and flushes the sidecar
    }
    assert_eq!(read_lru_sidecar(&side), want, "session order, oldest to newest");
    {
        // a warm session re-serving one key appends it — making it the
        // most recently served for GC's last-occurrence ranking
        let (oracle, _mon) = cache_session(CacheSessionMode::WriteThrough, p.clone()).unwrap();
        let _ = oracle.eval_batch(&reqs[..1]);
    }
    let twice = read_lru_sidecar(&side);
    assert_eq!(twice.len(), want.len() + 1);
    assert_eq!(twice.last(), Some(&want[0]));
    let _ = std::fs::remove_file(&p);
    let _ = std::fs::remove_file(&side);
}

// ---------------------------------------------------------------------------
// Worker orphan hygiene: a stale lease terminates the worker

#[test]
fn orphaned_worker_exits_cleanly_on_a_stale_lease() {
    // no coordinator ever beats this lease path -> the worker must exit
    // on its own within ~one lease timeout, NOT hang on stdin forever
    let lease = tmp("orphan.lease");
    let _ = std::fs::remove_file(&lease);
    let mut child = Command::new(exe())
        .arg("worker")
        .arg("--lease")
        .arg(&lease)
        .args(["--lease-ms", "300"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn repro worker");
    // hold stdin OPEN: an EOF would let the worker exit for the wrong
    // reason and mask a broken watchdog
    let _stdin = child.stdin.take();
    let t0 = Instant::now();
    let status = loop {
        if let Some(st) = child.try_wait().expect("try_wait") {
            break st;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "worker must exit within one lease timeout (plus slack), not hang"
        );
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(status.success(), "orphan exit is hygiene, not a fault: {status:?}");
    let mut stderr = String::new();
    child.stderr.take().unwrap().read_to_string(&mut stderr).unwrap();
    assert!(stderr.contains("lease stale"), "names the reason: {stderr}");
}

// ---------------------------------------------------------------------------
// CLI end to end: kill -9 mid-run, resume, byte-identical --out

#[test]
fn serve_cli_kill_minus_nine_then_resume_writes_byte_identical_output() {
    let journal = tmp("serve_kill.journal");
    let events = tmp("serve_kill.events.jsonl");
    let out_resumed = tmp("serve_kill_resumed.json");
    let out_ref = tmp("serve_kill_ref.json");
    for f in [&journal, &events, &out_resumed, &out_ref] {
        let _ = std::fs::remove_file(f);
    }
    let base = || {
        let mut cmd = Command::new(exe());
        cmd.args(["serve", "--workers", "2", "--tier", "mini", "--seed", "9"])
            .args(["--deadline-ms", "180000"]);
        cmd
    };

    // the uninterrupted reference
    let reference =
        base().arg("--out").arg(&out_ref).output().expect("run reference serve");
    assert!(
        reference.status.success(),
        "reference serve: {}",
        String::from_utf8_lossy(&reference.stderr)
    );

    // the journaled run, SIGKILLed once at least one shard has landed
    let mut child = base()
        .arg("--journal")
        .arg(&journal)
        .arg("--events")
        .arg(&events)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn journaled serve");
    let t0 = Instant::now();
    loop {
        let landed_enough =
            std::fs::metadata(&journal).map(|m| m.len() > 4096).unwrap_or(false);
        if landed_enough || child.try_wait().expect("try_wait").is_some() {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(180), "no shard ever landed");
        std::thread::sleep(Duration::from_millis(10));
    }
    let _ = child.kill(); // SIGKILL — no cleanup, no flush, no goodbye
    let _ = child.wait();

    // the killed run's event log tolerates a torn final line
    if let Ok(text) = std::fs::read_to_string(&events) {
        let (_, _torn) = parse_events_jsonl(&text)
            .unwrap_or_else(|e| panic!("killed event log must replay: {e}"));
    }

    // resume: must recover, finish, and write --out byte-identical
    let resumed = base()
        .arg("--journal")
        .arg(&journal)
        .arg("--resume")
        .arg("--out")
        .arg(&out_resumed)
        .output()
        .expect("run resumed serve");
    assert!(
        resumed.status.success(),
        "resume must exit 0; stderr: {}",
        String::from_utf8_lossy(&resumed.stderr)
    );
    let stdout = String::from_utf8_lossy(&resumed.stdout);
    assert!(stdout.contains("recovered from journal"), "stats name recovery: {stdout}");
    assert_eq!(
        std::fs::read(&out_resumed).unwrap(),
        std::fs::read(&out_ref).unwrap(),
        "resumed output is byte-identical to the uninterrupted run"
    );
    for f in [&journal, &events, &out_resumed, &out_ref] {
        let _ = std::fs::remove_file(f);
    }
}

#[test]
fn serve_cli_resume_refuses_a_corrupted_journal_in_band() {
    let p = tmp("corrupt.journal");
    let _ = std::fs::remove_file(&p);
    {
        let j = RunJournal::create(&p).unwrap();
        j.bind("serve", "cafe", 4).unwrap();
        j.record_done().unwrap();
    }
    // flip one payload byte inside the committed prefix
    let mut bytes = std::fs::read(&p).unwrap();
    let at = (JOURNAL_HEADER_BYTES + 16) as usize; // first payload byte
    bytes[at] ^= 0x01;
    std::fs::write(&p, &bytes).unwrap();
    let output = Command::new(exe())
        .args(["serve", "--workers", "1", "--tier", "mini", "--resume", "--journal"])
        .arg(&p)
        .output()
        .expect("run repro serve");
    assert!(!output.status.success(), "corruption must not resume");
    let stderr = String::from_utf8_lossy(&output.stderr);
    assert!(stderr.contains("error:"), "in-band, never a panic: {stderr}");
    let _ = std::fs::remove_file(&p);
}

#[test]
fn schedule_cli_resume_recovers_the_pass_and_reprints_identical_results() {
    let p = tmp("schedule.journal");
    let _ = std::fs::remove_file(&p);
    let run = |resume: bool| {
        let mut cmd = Command::new(exe());
        cmd.args(["schedule", "--tier", "mini", "--seed", "5", "--journal"]).arg(&p);
        if resume {
            cmd.arg("--resume");
        }
        let out = cmd.output().expect("run repro schedule");
        assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).to_string()
    };
    let first = run(false);
    let second = run(true);
    assert!(second.contains("recovered exhausted pass"), "{second}");
    let strip = |s: &str| {
        s.lines().filter(|l| !l.starts_with("journal")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(strip(&first), strip(&second), "resume reprints identical results");
    let _ = std::fs::remove_file(&p);
}

#[test]
fn sweep_cli_resume_writes_an_identical_grid_without_rerunning() {
    let p = tmp("sweep.journal");
    let o1 = tmp("sweep_first.json");
    let o2 = tmp("sweep_resumed.json");
    for f in [&p, &o1, &o2] {
        let _ = std::fs::remove_file(f);
    }
    let run = |resume: bool, out: &PathBuf| {
        let mut cmd = Command::new(exe());
        cmd.args(["sweep", "--tier", "mini", "--seed", "5", "--journal"]).arg(&p);
        if resume {
            cmd.arg("--resume");
        }
        cmd.arg("--out").arg(out);
        let r = cmd.output().expect("run repro sweep");
        assert!(r.status.success(), "stderr: {}", String::from_utf8_lossy(&r.stderr));
        String::from_utf8_lossy(&r.stdout).to_string()
    };
    run(false, &o1);
    let second = run(true, &o2);
    assert!(second.contains("recovered exhausted pass"), "{second}");
    assert_eq!(
        std::fs::read(&o1).unwrap(),
        std::fs::read(&o2).unwrap(),
        "resumed grid is byte-identical"
    );
    for f in [&p, &o1, &o2] {
        let _ = std::fs::remove_file(f);
    }
}

// ---------------------------------------------------------------------------
// CLI: cache repair / gc, and the journal guard on gc

#[test]
fn cache_repair_cli_recovers_a_torn_store_and_gc_honors_the_journal_guard() {
    let pairs = sample_pairs(6);
    let p = tmp("cli_repair.store");
    build_store(&p, &pairs);
    // tear it mid-record: stats must refuse, repair must recover
    let full = std::fs::read(&p).unwrap();
    let torn = tmp("cli_repair_torn.store");
    std::fs::write(&torn, &full[..full.len() * 2 / 3]).unwrap();
    let stats = Command::new(exe()).args(["cache", "stats"]).arg(&torn).output().unwrap();
    assert!(!stats.status.success(), "a torn store must not open");
    let repaired = tmp("cli_repaired.store");
    let rep = Command::new(exe())
        .args(["cache", "repair"])
        .arg(&torn)
        .arg("--out")
        .arg(&repaired)
        .output()
        .unwrap();
    assert!(rep.status.success(), "stderr: {}", String::from_utf8_lossy(&rep.stderr));
    let stats2 = Command::new(exe()).args(["cache", "stats"]).arg(&repaired).output().unwrap();
    assert!(stats2.status.success(), "repaired store opens and verifies");

    // gc with an ACTIVE journal refuses in-band; done journal proceeds
    let journal = tmp("cli_gc.journal");
    let _ = std::fs::remove_file(&journal);
    let j = RunJournal::create(&journal).unwrap();
    j.bind("serve", "cafe", 4).unwrap();
    let gced = tmp("cli_gced.store");
    let gc_cmd = || {
        let mut cmd = Command::new(exe());
        cmd.args(["cache", "gc"])
            .arg(&p)
            .args(["--max-bytes", "100000000"])
            .arg("--out")
            .arg(&gced)
            .arg("--journal")
            .arg(&journal);
        cmd
    };
    let refused = gc_cmd().output().unwrap();
    assert!(!refused.status.success());
    let stderr = String::from_utf8_lossy(&refused.stderr);
    assert!(stderr.contains("active"), "names the refusal: {stderr}");
    j.record_done().unwrap();
    drop(j);
    let allowed = gc_cmd().output().unwrap();
    assert!(
        allowed.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&allowed.stderr)
    );
    let stdout = String::from_utf8_lossy(&allowed.stdout);
    assert!(stdout.contains("identity"), "under budget names the identity: {stdout}");
    for f in [&p, &torn, &repaired, &journal, &gced] {
        let _ = std::fs::remove_file(f);
    }
}

// ---------------------------------------------------------------------------
// CLI flag scoping: misuse is an in-band error before any work starts

#[test]
fn journal_flags_are_scope_checked_in_band() {
    let check = |args: &[&str], needle: &str| {
        let out = Command::new(exe()).args(args).output().expect("run repro");
        assert!(!out.status.success(), "{args:?} must fail");
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert!(stderr.contains(needle), "{args:?}: expected `{needle}` in: {stderr}");
    };
    check(&["run", "--tier", "mini", "--journal", "x.journal"], "only meaningful");
    check(&["exp", "fig3", "--journal", "x.journal"], "only meaningful");
    check(&["serve", "--workers", "1", "--resume"], "--resume needs --journal");
    check(&["sweep", "--resume"], "--resume needs --journal");
    check(&["serve", "--workers", "1", "--journal"], "needs a file path");
    check(&["cache", "gc", "s.store", "--max-bytes", "10"], "--out");
    check(&["cache", "repair", "s.store"], "--out");
    check(
        &["schedule", "--tier", "mini", "--journal", "nope.journal", "--resume"],
        "journal",
    );
}
