//! Cross-module integration tests: DSL → perfmodel → agent → scheduler →
//! integrity, plus property tests on coordinator invariants (routing,
//! batching of attempts, scheduler state) via the in-house prop driver.

use ucutlass_repro::agent::controller::{run_problem, ControllerKind, Env, VariantSpec};
use ucutlass_repro::agent::{AttemptOutcome, ModelTier, SolutionKind};
use ucutlass_repro::dsl;
use ucutlass_repro::eval::{EvalRequest, Oracle};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::kernelbench::{find, suite};
use ucutlass_repro::metrics;
use ucutlass_repro::perfmodel::{CandidateConfig, CompiledCostModel, PerfModel};
use ucutlass_repro::scheduler::{self, Policy};
use ucutlass_repro::sol::{analyze, SolAnalysis, H100_SXM};
use ucutlass_repro::util::prop;
use ucutlass_repro::util::rng::{stream, MeasureSeq, StreamPath};

struct Fixture {
    model: PerfModel,
    problems: Vec<ucutlass_repro::kernelbench::Problem>,
    sols: Vec<SolAnalysis>,
    compiled: CompiledCostModel,
}

impl Fixture {
    fn new() -> Self {
        let problems = suite();
        let sols = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
        let model = PerfModel::new(H100_SXM.clone());
        let compiled = CompiledCostModel::compile(&model, &problems);
        Fixture { model, problems, sols, compiled }
    }

    fn env(&self) -> Env<'_> {
        Env::new(&self.model, &self.problems, &self.sols, &self.compiled)
    }

    fn ev(&self) -> Oracle<'_> {
        self.env().evaluator()
    }
}

// ---------------------------------------------------------------------------
// DSL end-to-end
// ---------------------------------------------------------------------------

#[test]
fn dsl_to_perfmodel_roundtrip() {
    let fx = Fixture::new();
    let src = "gemm().with_dtype(input=fp16, acc=fp32, output=fp32)\
        .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
        .with_threadblockshape(m=128, n=128, k=64).with_alignment(A=8, B=8, C=4)\
        .with_stages(3) >> bias() >> relu()";
    let compiled = dsl::compile(src).unwrap();
    let cfg = CandidateConfig::from_plan(&compiled.plan, true);
    let pidx = find(&fx.problems, "L2-76").unwrap();
    let ev = fx.ev();
    let t = ev.value(
        &EvalRequest::candidate(pidx, cfg).with_hash(compiled.plan.config_hash.clone()),
    );
    let sol = analyze(&fx.problems[pidx], &H100_SXM);
    assert!(t > sol.t_sol_fp16_ms, "model must respect the FP16 SOL floor");
    assert!(
        t < ev.value(&EvalRequest::baseline(pidx)),
        "library-grade fused kernel beats eager PyTorch"
    );
}

#[test]
fn dsl_bind_rejects_bad_dims_end_to_end() {
    let src = "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
        .with_layout(A=RowMajor, B=RowMajor, C=RowMajor).with_arch(sm_90a)\
        .with_operand_swap(true)";
    assert!(dsl::compile_bound(src, (4096, 4096, 4096)).is_ok());
    assert!(dsl::compile_bound(src, (2048, 4096, 4096)).is_err());
}

// ---------------------------------------------------------------------------
// Agent loop ↔ integrity ↔ scheduler
// ---------------------------------------------------------------------------

#[test]
fn full_problem_pipeline() {
    let fx = Fixture::new();
    let env = fx.env();
    let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mid);
    let pidx = find(&fx.problems, "L1-1").unwrap();
    let run = run_problem(&env, &spec, pidx, 7);
    assert_eq!(run.attempts.len(), 40);

    // integrity labels align 1:1 with attempts
    let pipeline = IntegrityPipeline::default();
    let labels = pipeline.review_run(&run, 7);
    assert_eq!(labels.len(), run.attempts.len());

    // the filtered best never beats the SOL-ceiling slack
    if let Some(best) = pipeline.filtered_best_ms(&run, 7) {
        assert!(best >= 0.9 * run.t_sol_fp16_ms);
    }

    // scheduler: fixed policy consumes everything; aggressive policy less
    let times: Vec<Option<f64>> = run.attempts.iter().map(|a| a.outcome.time_ms()).collect();
    let full = scheduler::stop_index(run.t_ref_ms, run.t_sol_fp16_ms, &times, &Policy::fixed());
    let eager = scheduler::stop_index(
        run.t_ref_ms,
        run.t_sol_fp16_ms,
        &times,
        &Policy { epsilon: 3.0, window: 4 },
    );
    assert_eq!(full, 40);
    assert!(eager <= full);
}

#[test]
fn dsl_attempts_are_real_compiles() {
    // every accepted DSL source in a run must re-compile through the real
    // µCUTLASS compiler
    let fx = Fixture::new();
    let env = fx.env();
    let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini);
    let mut sources = 0;
    for pidx in [0usize, 1, 2] {
        let run = run_problem(&env, &spec, pidx, 99);
        for a in &run.attempts {
            if let Some(src) = &a.dsl_source {
                dsl::compile(src).unwrap();
                sources += 1;
            }
        }
    }
    assert!(sources > 10, "expected plenty of DSL attempts, got {sources}");
}

#[test]
fn tool_time_saved_by_static_rejection() {
    // DslRejected attempts must cost (almost) no tool time
    let fx = Fixture::new();
    let env = fx.env();
    let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini);
    let mut rejected_time = 0.0;
    let mut rejected = 0;
    for pidx in 0..6 {
        let run = run_problem(&env, &spec, pidx, 3);
        for a in &run.attempts {
            if matches!(a.outcome, AttemptOutcome::DslRejected) {
                rejected += 1;
                rejected_time += a.tool_time_s;
            }
        }
    }
    if rejected > 0 {
        assert!(rejected_time / rejected as f64 <= 2.0);
    }
}

// ---------------------------------------------------------------------------
// Property tests (coordinator invariants)
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_stop_monotone_in_epsilon() {
    // a larger ε can only stop earlier (or at the same attempt)
    prop::check("stop-monotone-eps", 200, |rng| {
        let t_ref = rng.range_f64(1.0, 100.0);
        let t_sol = t_ref * rng.range_f64(0.01, 0.5);
        let times: Vec<Option<f64>> = (0..20)
            .map(|_| {
                if rng.chance(0.7) {
                    Some(t_sol * rng.range_f64(0.8, 20.0))
                } else {
                    None
                }
            })
            .collect();
        let e1 = rng.range_f64(0.1, 1.5);
        let e2 = e1 + rng.range_f64(0.1, 2.0);
        let s1 = scheduler::stop_index(t_ref, t_sol, &times, &Policy { epsilon: e1, window: 0 });
        let s2 = scheduler::stop_index(t_ref, t_sol, &times, &Policy { epsilon: e2, window: 0 });
        assert!(s2 <= s1, "eps {e2} stopped later ({s2}) than eps {e1} ({s1})");
    });
}

#[test]
fn prop_scheduler_stop_monotone_in_window() {
    prop::check("stop-monotone-window", 200, |rng| {
        let t_ref = rng.range_f64(1.0, 100.0);
        let t_sol = t_ref * 0.1;
        let times: Vec<Option<f64>> = (0..30)
            .map(|_| if rng.chance(0.6) { Some(rng.range_f64(0.5, 120.0)) } else { None })
            .collect();
        let w1 = 2 + rng.below(6) as u32;
        let w2 = w1 + 1 + rng.below(8) as u32;
        let s1 = scheduler::stop_index(t_ref, t_sol, &times, &Policy { epsilon: f64::INFINITY, window: w1 });
        let s2 = scheduler::stop_index(t_ref, t_sol, &times, &Policy { epsilon: f64::INFINITY, window: w2 });
        assert!(s1 <= s2, "larger window must not stop earlier");
    });
}

#[test]
fn prop_fastp_is_complementary_cdf() {
    prop::check("fastp-ccdf", 100, |rng| {
        let n = 5 + rng.below(40);
        let speedups: Vec<f64> = (0..n).map(|_| rng.range_f64(0.0, 8.0)).collect();
        let grid = metrics::default_grid();
        let fp = metrics::fast_p(&speedups, &grid);
        for w in fp.pct.windows(2) {
            assert!(w[0] + 1e-9 >= w[1], "Fast-p must be non-increasing");
        }
        assert!(fp.pct.iter().all(|&p| (0.0..=100.0).contains(&p)));
    });
}

#[test]
fn prop_perfmodel_noise_mean_preserving() {
    prop::check("noise-mean", 20, |rng| {
        let fx = Fixture::new();
        let ev = fx.ev();
        let pidx = rng.below(fx.problems.len());
        let cfg = CandidateConfig::library((128, 128, 32), ucutlass_repro::dsl::DType::Fp32);
        let t0 = ev.value(&EvalRequest::candidate(pidx, cfg.clone()));
        let mut seq = MeasureSeq::new(StreamPath::new(
            rng.next_u64(),
            &[stream::MEASURE, stream::PROP_CASE, pidx as u64],
        ));
        let mean: f64 = (0..200)
            .map(|_| {
                ev.value(&EvalRequest::measured(pidx, cfg.clone(), seq.next_stream()))
            })
            .sum::<f64>()
            / 200.0;
        assert!((mean / t0 - 1.0).abs() < 0.02, "noise must be mean-preserving");
    });
}

#[test]
fn prop_runs_deterministic_across_replays() {
    let fx = Fixture::new();
    let env = fx.env();
    prop::check("replay-deterministic", 12, |rng| {
        let pidx = rng.below(fx.problems.len());
        let seed = rng.next_u64();
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);
        let a = run_problem(&env, &spec, pidx, seed);
        let b = run_problem(&env, &spec, pidx, seed);
        assert_eq!(a.attempts.len(), b.attempts.len());
        for (x, y) in a.attempts.iter().zip(&b.attempts) {
            assert_eq!(x.outcome.time_ms(), y.outcome.time_ms());
            assert_eq!(x.tokens, y.tokens);
        }
    });
}

#[test]
fn prop_gaming_never_survives_perfect_lgd() {
    let fx = Fixture::new();
    let env = fx.env();
    let pipeline =
        IntegrityPipeline { lgd_detect_rate: 1.0, ..IntegrityPipeline::default() };
    prop::check("lgd-perfect", 10, |rng| {
        let pidx = rng.below(fx.problems.len());
        let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Max);
        let run = run_problem(&env, &spec, pidx, rng.next_u64());
        let labels = pipeline.review_run(&run, 5);
        for (a, l) in run.attempts.iter().zip(&labels) {
            if matches!(a.kind, SolutionKind::Gaming(_)) && a.outcome.time_ms().is_some() {
                assert!(!l.accepted(), "gamed attempt accepted: {a:?} -> {l:?}");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// Online scheduler + parallel engine (ADR-002)
// ---------------------------------------------------------------------------

#[test]
fn e2e_parallel_online_determinism() {
    // the full chain: sessions → online scheduler → parallel engine must
    // agree with the serial fixed-budget reference across module borders
    use ucutlass_repro::exec;
    use ucutlass_repro::experiments::runner::Bench;

    let bench = Bench::new();
    let env = bench.env();
    let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);

    // serial fixed-budget reference
    let reference = ucutlass_repro::experiments::run_variant(&bench, &spec, 99, None);
    // parallel engine, 4 jobs
    let par = exec::run_variant_jobs(&bench, &spec, 99, None, 4);
    assert_eq!(par, reference);

    // online under the fixed policy reproduces the reference…
    let fixed = scheduler::run_online(&env, &spec, 99, &Policy::fixed(), 4);
    assert_eq!(fixed.log.runs, reference.runs);

    // …and under a real policy every stop matches the offline prediction
    let policy = Policy { epsilon: 1.0, window: 8 };
    let online = scheduler::run_online(&env, &spec, 99, &policy, 4);
    for (run, full) in online.log.runs.iter().zip(&reference.runs) {
        let times: Vec<Option<f64>> =
            full.attempts.iter().map(|a| a.outcome.time_ms()).collect();
        let predicted = scheduler::stop_index(full.t_ref_ms, full.t_sol_fp16_ms, &times, &policy);
        assert_eq!(run.attempts.len(), predicted);
        assert_eq!(run.attempts[..], full.attempts[..predicted]);
    }
    assert!(online.attempts_total() <= fixed.attempts_total());
}
