//! PJRT runtime integration tests: require `make artifacts` to have run.
//! Each test is skipped (not failed) when artifacts/ is absent so that
//! `cargo test` works in a fresh checkout; CI runs `make test` which builds
//! artifacts first.

use ucutlass_repro::dsl;
use ucutlass_repro::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(_) => {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_covers_all_python_problems() {
    let Some(rt) = runtime() else { return };
    for name in [
        "gemm_square", "gemm_tall_skinny", "batched_gemm", "gemm_bias_relu",
        "gemm_divide_gelu", "gemm_silu_scale", "gemm_sigmoid_residual",
        "softmax", "rmsnorm", "layernorm", "cumsum", "attention",
        "causal_attention", "mlp_block",
    ] {
        let p = rt.manifest.problems.get(name).unwrap_or_else(|| panic!("missing {name}"));
        assert!(!p.variants.is_empty(), "{name} has no variants");
        assert!(!p.reference.is_empty());
    }
}

#[test]
fn gemm_variants_match_reference_numerically() {
    let Some(mut rt) = runtime() else { return };
    let prob = rt.manifest.problems.get("gemm_square").cloned().unwrap();
    for variant in prob.variants.keys() {
        let rep = rt.validate_variant("gemm_square", variant, 11).unwrap();
        assert!(rep.pass, "gemm_square/{variant}: max|err|={}", rep.max_abs_err);
        assert!(rep.elems == 256 * 256);
    }
}

#[test]
fn fused_epilogue_problems_validate() {
    let Some(mut rt) = runtime() else { return };
    for pname in ["gemm_bias_relu", "gemm_divide_gelu", "gemm_silu_scale"] {
        let prob = rt.manifest.problems.get(pname).cloned().unwrap();
        let variant = prob.variants.keys().next().unwrap().clone();
        let rep = rt.validate_variant(pname, &variant, 23).unwrap();
        assert!(rep.pass, "{pname}/{variant}: {}", rep.max_abs_err);
    }
}

#[test]
fn attention_and_norms_validate() {
    let Some(mut rt) = runtime() else { return };
    for pname in ["attention", "causal_attention", "rmsnorm", "layernorm", "softmax", "cumsum"] {
        let prob = rt.manifest.problems.get(pname).cloned().unwrap();
        for variant in prob.variants.keys() {
            let rep = rt.validate_variant(pname, variant, 31).unwrap();
            assert!(rep.pass, "{pname}/{variant}: {}", rep.max_abs_err);
        }
    }
}

#[test]
fn mlp_pipeline_validates() {
    let Some(mut rt) = runtime() else { return };
    let prob = rt.manifest.problems.get("mlp_block").cloned().unwrap();
    for variant in prob.variants.keys() {
        let rep = rt.validate_variant("mlp_block", variant, 41).unwrap();
        assert!(rep.pass, "mlp_block/{variant}: {}", rep.max_abs_err);
    }
}

#[test]
fn dsl_plan_selects_executable_artifact() {
    let Some(mut rt) = runtime() else { return };
    let src = "gemm().with_dtype(input=fp32, acc=fp32, output=fp32)\
        .with_layout(A=RowMajor, B=ColumnMajor, C=RowMajor).with_arch(sm_90a)\
        .with_threadblockshape(m=64, n=64, k=64).with_alignment(A=4, B=4, C=4)";
    let compiled = dsl::compile(src).unwrap();
    let prob = rt.manifest.problems.get("gemm_square").cloned().unwrap();
    let variant = Runtime::select_variant(&prob, &compiled.plan).unwrap();
    assert_eq!(variant, "t64x64x64_fp32");
    let rep = rt.validate_variant("gemm_square", &variant, 51).unwrap();
    assert!(rep.pass);
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(mut rt) = runtime() else { return };
    let before = rt.cached();
    rt.validate_variant("softmax", "rows16", 61).unwrap();
    let mid = rt.cached();
    rt.validate_variant("softmax", "rows16", 62).unwrap();
    assert_eq!(rt.cached(), mid, "second validation must reuse compiled executables");
    assert!(mid >= before + 2, "reference + candidate should be cached");
}

#[test]
fn pjrt_evaluator_validates_artifact_backed_problems() {
    // the eval-layer face of the runtime (ADR-003): candidate requests map
    // onto AOT variants and return numeric-validation responses
    use ucutlass_repro::dsl::DType;
    use ucutlass_repro::eval::{EvalRequest, Evaluator, PjrtEvaluator};
    use ucutlass_repro::kernelbench::suite;
    use ucutlass_repro::perfmodel::CandidateConfig;

    if runtime().is_none() {
        return;
    }
    let problems = suite();
    let ev = PjrtEvaluator::open("artifacts", problems.clone());
    assert!(ev.available());
    let reqs: Vec<EvalRequest> = problems
        .iter()
        .enumerate()
        .filter(|(_, p)| p.artifact.is_some())
        .map(|(i, _)| {
            EvalRequest::candidate(i, CandidateConfig::library((64, 64, 64), DType::Fp32))
        })
        .collect();
    assert!(!reqs.is_empty());
    let responses = ev.eval_batch(&reqs);
    for (r, resp) in reqs.iter().zip(&responses) {
        assert!(resp.pass, "{}: {:?}", r.key(), resp.detail);
        assert_eq!(*resp, ev.eval(r), "batch must equal scalar");
    }
}
