//! ADR-009 acceptance tests: golden diagnostics over the adversarial
//! corpus (stable rule IDs, spans, machine-applicable fixes), cross-
//! namespace code uniqueness, the prune twin-run property (pruned configs
//! are never evaluated, yet the run's best trajectory, integrity labels
//! and filtered speedups are bitwise identical to the unpruned twin), and
//! fuzz-ish hostile inputs that must never panic.

use std::sync::atomic::{AtomicU64, Ordering};

use ucutlass_repro::agent::controller::{ControllerKind, Env, VariantSpec};
use ucutlass_repro::agent::{run_problem, AttemptOutcome, ModelTier};
use ucutlass_repro::analyze::{analyze_source, deny_count, Diagnostic, RuleId, Severity};
use ucutlass_repro::dsl::DslErrorKind;
use ucutlass_repro::eval::{
    EvalRequest, EvalResponse, Evaluator, MeasureKind, OwnedAnalytic,
};
use ucutlass_repro::integrity::{IntegrityPipeline, ReviewLabel};
use ucutlass_repro::kernelbench::suite;
use ucutlass_repro::perfmodel::{CompiledCostModel, PerfModel};
use ucutlass_repro::sol::{analyze as sol_analyze, SolAnalysis, H100_SXM};

fn corpus(name: &str) -> String {
    let path = format!("../examples/lint/{name}");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

fn diags(name: &str) -> (String, Vec<Diagnostic>) {
    let src = corpus(name);
    let diags = analyze_source(&src, None)
        .unwrap_or_else(|e| panic!("{name} must compile: {e}"));
    (src, diags)
}

// -- golden diagnostics over the corpus --------------------------------------

#[test]
fn golden_clean_program_is_quiet() {
    let (_, d) = diags("clean.dsl");
    assert!(d.is_empty(), "clean.dsl must produce no diagnostics: {d:?}");
}

#[test]
fn golden_accumulator_drop() {
    let (src, d) = diags("accumulator_drop.dsl");
    assert_eq!(d.len(), 1, "{d:?}");
    assert_eq!(d[0].rule.code(), "A202");
    assert_eq!(d[0].severity, Severity::Deny);
    assert_eq!(d[0].span.expect("span").slice(&src), "scale(0.0)");
    assert_eq!(deny_count(&d, false), 1);
    // the fix removes the op (and its `>>`) and the result is clean
    let fixed = d[0].fix.as_ref().expect("fix").apply(&src);
    assert!(!fixed.contains("scale"));
    assert!(analyze_source(&fixed, None).unwrap().is_empty(), "{fixed}");
}

#[test]
fn golden_constant_output_is_denied() {
    let (src, d) = diags("near_sol_implausible.dsl");
    assert_eq!(d.len(), 1, "{d:?}");
    assert_eq!(d[0].rule.code(), "A103");
    assert_eq!(d[0].severity, Severity::Deny);
    assert_eq!(d[0].span.expect("span").slice(&src), "clip(5.0, 5.0)");
    assert_eq!(deny_count(&d, false), 1);
}

#[test]
fn golden_dead_epilogue_store() {
    let (src, d) = diags("dead_epilogue.dsl");
    assert_eq!(d.len(), 1, "{d:?}");
    assert_eq!(d[0].rule.code(), "A201");
    assert_eq!(d[0].severity, Severity::Warn);
    assert_eq!(d[0].span.expect("span").slice(&src), "aux_store(t0)");
    // warnings deny only under escalation
    assert_eq!(deny_count(&d, false), 0);
    assert_eq!(deny_count(&d, true), 1);
    let fixed = d[0].fix.as_ref().expect("fix").apply(&src);
    assert!(!fixed.contains("aux_store") && fixed.contains("relu"));
    assert!(analyze_source(&fixed, None).unwrap().is_empty(), "{fixed}");
}

#[test]
fn golden_identity_chain_fixes_to_fixpoint() {
    let (src, d) = diags("identity_chain.dsl");
    assert_eq!(d.len(), 2, "{d:?}");
    // sorted by span offset: scale(1.0) precedes leaky_relu(alpha=1.0)
    assert_eq!(d[0].rule.code(), "A203");
    assert_eq!(d[1].rule.code(), "A203");
    assert_eq!(d[0].span.expect("span").slice(&src), "scale(1.0)");
    assert_eq!(d[1].span.expect("span").slice(&src), "leaky_relu(alpha=1.0)");
    // applying the first fix and re-analyzing converges to a clean program
    let mut cur = src;
    for _ in 0..3 {
        let ds = analyze_source(&cur, None).unwrap();
        match ds.first() {
            None => break,
            Some(first) => cur = first.fix.as_ref().expect("fix").apply(&cur),
        }
    }
    assert!(analyze_source(&cur, None).unwrap().is_empty(), "{cur}");
}

#[test]
fn golden_constraint_cliff_notes() {
    let (src, d) = diags("constraint_cliff.dsl");
    let codes: Vec<&str> = d.iter().map(|x| x.rule.code()).collect();
    assert_eq!(codes, ["C402", "C403"], "{d:?}");
    assert!(d.iter().all(|x| x.severity == Severity::Note));
    // notes never reach deny, even under --deny-warnings
    assert_eq!(deny_count(&d, true), 0);
    // fix-its step away from the cliff
    assert_eq!(d[0].fix.as_ref().expect("fix").replacement, "with_stages(11)");
    assert_eq!(
        d[1].fix.as_ref().expect("fix").replacement,
        "with_alignment(A=16, B=16, C=16)"
    );
    let fixed = d[0].fix.as_ref().unwrap().apply(&src);
    let codes: Vec<&str> = analyze_source(&fixed, None)
        .unwrap()
        .iter()
        .map(|x| x.rule.code())
        .collect();
    assert_eq!(codes, ["C403"], "stage fix clears C402 only");
}

#[test]
fn corpus_diagnostics_are_stable_json() {
    // every corpus diagnostic serializes with the shared code/severity/
    // message/why/span/fix schema
    for name in [
        "accumulator_drop.dsl",
        "near_sol_implausible.dsl",
        "dead_epilogue.dsl",
        "identity_chain.dsl",
        "constraint_cliff.dsl",
    ] {
        let (_, d) = diags(name);
        assert!(!d.is_empty(), "{name} must diagnose");
        for x in &d {
            let j = x.to_json();
            assert_eq!(j.get("code").and_then(|v| v.as_str()), Some(x.rule.code()));
            assert_eq!(
                j.get("severity").and_then(|v| v.as_str()),
                Some(x.severity.name())
            );
            assert!(j.get("why").and_then(|v| v.as_str()).is_some_and(|w| !w.is_empty()));
            assert!(j.get("span").is_some() && j.get("fix").is_some());
        }
    }
}

// -- code registry: one namespace across compiler errors and analyzer rules --

#[test]
fn error_and_rule_codes_share_one_namespace() {
    let mut seen = std::collections::HashSet::new();
    for k in DslErrorKind::ALL {
        assert!(seen.insert(k.code()), "duplicate code {}", k.code());
    }
    for r in RuleId::ALL {
        assert!(seen.insert(r.code()), "duplicate code {}", r.code());
        assert_eq!(RuleId::parse_code(r.code()), Some(r));
        assert!(!r.summary().is_empty());
    }
    assert_eq!(seen.len(), DslErrorKind::ALL.len() + RuleId::ALL.len());
}

// -- prune twin-run property (tentpole acceptance) ---------------------------

/// Counts evaluator traffic by request kind while answering analytically —
/// what "pruned configs are never evaluated" is measured against.
struct CountingOracle {
    inner: OwnedAnalytic,
    measured: AtomicU64,
    total: AtomicU64,
}

impl CountingOracle {
    fn new() -> CountingOracle {
        CountingOracle {
            inner: OwnedAnalytic::new(),
            measured: AtomicU64::new(0),
            total: AtomicU64::new(0),
        }
    }

    fn measured(&self) -> u64 {
        self.measured.load(Ordering::Relaxed)
    }

    fn total(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }
}

impl Evaluator for CountingOracle {
    fn eval_batch(&self, reqs: &[EvalRequest]) -> Vec<EvalResponse> {
        self.total.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let m = reqs
            .iter()
            .filter(|r| matches!(r.kind, MeasureKind::Measured))
            .count();
        self.measured.fetch_add(m as u64, Ordering::Relaxed);
        self.inner.eval_batch(reqs)
    }
}

#[test]
fn prune_twins_agree_bitwise_and_save_trials() {
    let problems = suite();
    let sols: Vec<SolAnalysis> = problems.iter().map(|p| sol_analyze(p, &H100_SXM)).collect();
    let model = PerfModel::new(H100_SXM.clone());
    let compiled = CompiledCostModel::compile(&model, &problems);
    let pipe = IntegrityPipeline::default();
    let seed = 7u64;

    let mut total_pruned = 0usize;
    for tier in [ModelTier::Mini, ModelTier::Max] {
        let spec = VariantSpec::new(ControllerKind::InPromptSol, true, tier);
        let spec_prune = spec.with_prune();
        for pidx in 0..problems.len() {
            let off_oracle = CountingOracle::new();
            let env =
                Env::new(&model, &problems, &sols, &compiled).with_oracle(Some(&off_oracle));
            let off = run_problem(&env, &spec, pidx, seed);

            let on_oracle = CountingOracle::new();
            let env =
                Env::new(&model, &problems, &sols, &compiled).with_oracle(Some(&on_oracle));
            let on = run_problem(&env, &spec_prune, pidx, seed);

            assert_eq!(off.attempts.len(), on.attempts.len());
            let mut pruned_here = 0usize;
            for (a_off, a_on) in off.attempts.iter().zip(&on.attempts) {
                if let AttemptOutcome::Pruned { .. } = a_on.outcome {
                    pruned_here += 1;
                    // the twin measured the same config — and it did not win
                    assert!(
                        matches!(a_off.outcome, AttemptOutcome::Correct { .. }),
                        "pruned twin must be a measured Correct attempt"
                    );
                    assert_eq!(a_off.dsl_source, a_on.dsl_source);
                    assert_eq!(a_off.config, a_on.config);
                    assert_eq!(a_off.dsl_plan, a_on.dsl_plan);
                    assert_eq!(a_off.minor_issue, a_on.minor_issue, "rng draw alignment");
                    assert_eq!(a_on.outcome.time_ms(), None);
                } else {
                    // everything the pruner let through is field-for-field
                    // identical — pruning perturbs nothing downstream
                    assert_eq!(a_off, a_on);
                }
            }
            total_pruned += pruned_here;

            // best-so-far trajectory is identical at every step: pruned
            // attempts were provably non-improving
            for n in 0..=off.attempts.len() {
                assert_eq!(off.best_time_after(n), on.best_time_after(n), "n={n}");
            }

            // integrity review: labels at surviving indices are bitwise
            // equal (the pruned branch consumes the twin's RNG draws), and
            // pruned attempts label NoIssues
            let labels_off = pipe.review_run(&off, seed);
            let labels_on = pipe.review_run(&on, seed);
            for (i, (lo, ln)) in labels_off.iter().zip(&labels_on).enumerate() {
                if matches!(on.attempts[i].outcome, AttemptOutcome::Pruned { .. }) {
                    assert_eq!(*ln, ReviewLabel::NoIssues);
                } else {
                    assert_eq!(lo, ln, "label desync at attempt {i}");
                }
            }

            // the headline aggregation is bitwise unchanged
            assert_eq!(
                pipe.filtered_speedup(&off, seed).map(f64::to_bits),
                pipe.filtered_speedup(&on, seed).map(f64::to_bits),
                "filtered speedup must be bitwise identical (pidx={pidx})"
            );

            // pruned configs never reached the evaluator
            assert_eq!(
                off_oracle.measured() - on_oracle.measured(),
                pruned_here as u64,
                "each pruned attempt saves exactly one measured trial"
            );
            if pruned_here > 0 {
                assert!(on_oracle.total() < off_oracle.total());
            }
        }
    }
    assert!(total_pruned > 0, "the suite must exercise the prune gate");
}

// -- hostile inputs must never panic -----------------------------------------

#[test]
fn hostile_inputs_never_panic() {
    let hostile = [
        "",
        " ",
        "(",
        ")))",
        "gemm(",
        "gemm() >>",
        ">> relu()",
        "pipeline(",
        "pipeline()",
        "pipeline(gemm(),)",
        "gemm() >> custom('unterminated",
        "gemm() >> custom('f(x))', inputs={'y':)",
        "gemm().with_stages(999999999999999999999999)",
        "gemm().with_threadblockshape(m=-1, n=0, k=0)",
        "gemm().with_dtype(input=fp999)",
        "gemm() # comment only\n",
        "gemm()\u{0}\u{1}\u{7f}",
        "gemm() >> scale(\u{3c0})",
        "transpose(input, NCL, NLC)",
        "gemm().with_arch(sm_90a).with_arch(sm_90a)",
    ];
    for src in hostile {
        // Err is fine; panicking is not
        let _ = analyze_source(src, None);
    }
    // sliding truncations of every corpus file
    for name in [
        "clean.dsl",
        "accumulator_drop.dsl",
        "near_sol_implausible.dsl",
        "dead_epilogue.dsl",
        "identity_chain.dsl",
        "constraint_cliff.dsl",
    ] {
        let src = corpus(name);
        for i in 0..=src.len() {
            if src.is_char_boundary(i) {
                let _ = analyze_source(&src[..i], None);
            }
        }
    }
}

// -- compile errors carry stable E-codes through the lint surface ------------

#[test]
fn compile_errors_surface_stable_codes() {
    let err = analyze_source("gemm() >> nonsense()", None).unwrap_err();
    let j = err.to_json();
    let code = j.get("code").and_then(|v| v.as_str()).expect("code");
    assert!(code.starts_with('E'), "compiler errors use the E-namespace: {code}");
    assert!(DslErrorKind::ALL.iter().any(|k| k.code() == code));
}

// -- the repro lint CLI: exit codes over the corpus --------------------------

mod cli {
    use std::process::Command;

    fn lint(args: &[&str]) -> std::process::Output {
        Command::new(env!("CARGO_BIN_EXE_repro"))
            .arg("lint")
            .args(args)
            .output()
            .expect("spawn repro lint")
    }

    #[test]
    fn exit_codes_match_deny_counts() {
        // clean + notes-only: 0; single-deny corpus: 1; warn escalation: 1;
        // compile error: 101
        assert_eq!(lint(&["../examples/lint/clean.dsl"]).status.code(), Some(0));
        assert_eq!(
            lint(&["../examples/lint/constraint_cliff.dsl", "--deny-warnings"])
                .status
                .code(),
            Some(0)
        );
        assert_eq!(
            lint(&["../examples/lint/accumulator_drop.dsl"]).status.code(),
            Some(1)
        );
        assert_eq!(
            lint(&["../examples/lint/near_sol_implausible.dsl", "--json"])
                .status
                .code(),
            Some(1)
        );
        assert_eq!(
            lint(&["../examples/lint/dead_epilogue.dsl"]).status.code(),
            Some(0),
            "warnings alone do not fail the lint"
        );
        assert_eq!(
            lint(&["../examples/lint/dead_epilogue.dsl", "--deny-warnings"])
                .status
                .code(),
            Some(1)
        );
    }

    #[test]
    fn json_mode_reports_codes() {
        let out = lint(&["../examples/lint/accumulator_drop.dsl", "--json"]);
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("\"A202\""), "{text}");
        assert!(text.contains("\"deny_count\""), "{text}");
    }

    #[test]
    fn compile_error_exits_101() {
        let out = lint(&["../examples/lint/missing_file.dsl"]);
        assert_ne!(out.status.code(), Some(0));
        // a syntactically broken program (via stdin) exits 101 with an E-code
        use std::io::Write;
        let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(["lint", "-", "--json"])
            .stdin(std::process::Stdio::piped())
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::piped())
            .spawn()
            .expect("spawn repro lint -");
        child
            .stdin
            .take()
            .expect("stdin")
            .write_all(b"gemm( >> relu()")
            .expect("write stdin");
        let out = child.wait_with_output().expect("wait");
        assert_eq!(out.status.code(), Some(101));
        let text = String::from_utf8_lossy(&out.stdout);
        assert!(text.contains("\"code\""), "{text}");
    }
}
