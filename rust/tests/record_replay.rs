//! Record/replay golden tests (ADR-004 acceptance): recording a suite run
//! must be transparent, and strict replay from the trace — with the
//! analytic backend disabled — must reproduce the `RunLog`s
//! field-for-field (and byte-for-byte as JSON artifacts) at any job
//! count. Keys are derived-stream identities, so a trace recorded under
//! `--jobs 4` serves a `--jobs 1` replay and vice versa.

use ucutlass_repro::agent::controller::{ControllerKind, Env, VariantSpec};
use ucutlass_repro::agent::{run_problem, ModelTier, RunLog};
use ucutlass_repro::eval::{OwnedAnalytic, RecordingEvaluator, TraceEvaluator};
use ucutlass_repro::exec;
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::kernelbench::suite;
use ucutlass_repro::mantis::MantisConfig;
use ucutlass_repro::perfmodel::{CompiledCostModel, PerfModel};
use ucutlass_repro::sol::{analyze, SolAnalysis, H100_SXM};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ucutlass_rr_{name}_{}.jsonl", std::process::id()))
}

/// One flat variant (fans out per problem) + one orchestrated default
/// (cross-memory on → a whole-variant task), as in the shard/merge golden
/// test: together they cover both task shapes of ADR-002.
fn work() -> Vec<(VariantSpec, Option<MantisConfig>)> {
    vec![
        (VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mini), None),
        (
            VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini),
            Some(MantisConfig::default()),
        ),
    ]
}

#[test]
fn record_replay_golden_runlogs_identical_at_jobs_1_and_4() {
    let path = tmp("golden");
    let work = work();
    let seed = 2025;

    // reference: the plain analytic run
    let bench = Bench::new();
    let reference: Vec<RunLog> = exec::eval_variants(&bench, &work, seed, 1);

    // record under --jobs 4: the recorder must be transparent, and the
    // trace key set must be job-count independent
    let mut bench_rec = Bench::new();
    let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &path).unwrap();
    let rec_monitor = rec.monitor();
    bench_rec.set_oracle(Box::new(rec));
    let recorded = exec::eval_variants(&bench_rec, &work, seed, 4);
    assert_eq!(recorded, reference, "recording must not perturb the run");
    assert!(rec_monitor.recorded() > 0);
    drop(bench_rec); // dropping the recorder flushes the trace
    assert_eq!(rec_monitor.io_error(), None);

    // strict replay (analytic backend disabled): field-for-field and
    // byte-for-byte identical, serial and parallel
    for jobs in [1usize, 4] {
        let mut bench_rep = Bench::new();
        let trace = TraceEvaluator::load(&path).unwrap();
        let monitor = trace.monitor();
        bench_rep.set_oracle(Box::new(trace));
        let replayed = exec::eval_variants(&bench_rep, &work, seed, jobs);
        assert_eq!(
            monitor.misses(),
            0,
            "jobs={jobs}: first miss: {:?}",
            monitor.first_miss()
        );
        assert!(monitor.served() > 0);
        assert_eq!(replayed, reference, "jobs={jobs}: replay must be field-for-field exact");
        for (r, x) in replayed.iter().zip(&reference) {
            assert_eq!(
                r.to_json().to_string(),
                x.to_json().to_string(),
                "jobs={jobs}: persisted artifacts must be byte-identical"
            );
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn record_replay_strict_miss_of_an_uncovered_run_is_in_band() {
    // replaying a *different* seed against a recorded trace must complete
    // without panicking and report every miss through the monitor
    let path = tmp("uncovered");
    let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini);

    let problems = suite();
    let sols: Vec<SolAnalysis> = problems.iter().map(|p| analyze(p, &H100_SXM)).collect();
    let model = PerfModel::new(H100_SXM.clone());
    let compiled = CompiledCostModel::compile(&model, &problems);

    let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &path).unwrap();
    let env = Env::new(&model, &problems, &sols, &compiled).with_oracle(Some(&rec));
    let recorded = run_problem(&env, &spec, 0, 7);
    drop(rec);

    let trace = TraceEvaluator::load(&path).unwrap();
    let monitor = trace.monitor();
    let env = Env::new(&model, &problems, &sols, &compiled).with_oracle(Some(&trace));
    // same seed: covered, bit-identical
    assert_eq!(run_problem(&env, &spec, 0, 7), recorded);
    assert_eq!(monitor.misses(), 0);
    // different seed: not covered — completes, and the monitor reports it
    let _ = run_problem(&env, &spec, 0, 8);
    assert!(monitor.misses() > 0);
    assert!(monitor.check().is_err());
    let _ = std::fs::remove_file(&path);
}
