//! Smoke tests over the experiment drivers: each figure function must run
//! end-to-end on a fresh context and produce non-trivial output. Run in
//! debug these take a couple of minutes total; they exercise every module
//! of the system (the real "does the whole thing hang together" check).

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::experiments::figures::ExpCtx;
use ucutlass_repro::experiments::runner::{run_variant, Bench};
use ucutlass_repro::experiments::{archive, figures};
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::metrics;
use ucutlass_repro::scheduler;

fn ctx(name: &str) -> ExpCtx {
    ExpCtx::new(std::env::temp_dir().join(format!("ucutlass_smoke_{name}")), 4242)
}

#[test]
fn fig3_shape_matches_paper() {
    let mut c = ctx("fig3");
    let out = figures::fig3(&mut c);
    // 12 variant rows
    assert_eq!(out.matches("[gpt-").count(), 12, "{out}");
}

#[test]
fn fig7_scheduler_sweep_saves_tokens() {
    let mut c = ctx("fig7");
    let out = figures::fig7(&mut c);
    assert!(out.contains("ε=25%"));
    assert!(out.contains("w=4"));
}

#[test]
fn fig9_best_policies_gain() {
    let mut c = ctx("fig9");
    let out = figures::fig9(&mut c);
    // at least some variants should show a >1x efficiency gain
    assert!(out.contains("x"), "{out}");
}

#[test]
fn fig12_shows_inflation() {
    let mut c = ctx("fig12");
    let out = figures::fig12(&mut c);
    assert!(out.contains("inflation"));
}

#[test]
fn fig14_archive_comparison() {
    let mut c = ctx("fig14");
    let out = figures::fig14(&mut c);
    assert!(out.contains("archive"));
    assert!(out.contains("FP16 SOL"));
}

#[test]
fn scheduler_budget_tradeoff_holds() {
    // paper RQ4 shape: some policy saves ≥15% tokens at ≥90% retention
    let bench = Bench::new();
    let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Max);
    let log = run_variant(&bench, &spec, 777, None);
    let pipeline = IntegrityPipeline::default();
    let sweep = scheduler::sweep(&log, &pipeline, 777);
    let ok = sweep
        .iter()
        .any(|r| r.token_savings() >= 0.15 && r.geomean_retention() >= 0.90);
    assert!(ok, "no policy achieved 15% savings at 90% retention");
}

#[test]
fn archive_geomean_below_ours() {
    // paper §6.5: all three µC+SOL tiers beat the evolutionary archive
    let bench = Bench::new();
    let env = bench.env();
    let pipeline = IntegrityPipeline::default();
    let params = archive::EvoParams::default();
    let mut archive_sp = Vec::new();
    for pidx in 0..bench.problems.len() {
        let a = archive::generate_archive(&env, pidx, &params, 55);
        let (s, _) = archive::review_archive(&env, pidx, &a, &pipeline, 55);
        archive_sp.push(if s > 0.0 { s } else { 1.0 });
    }
    let geo_archive = metrics::geomean_speedup(&archive_sp);

    let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini);
    let log = run_variant(&bench, &spec, 55, None);
    let ours: Vec<f64> = log
        .runs
        .iter()
        .map(|r| pipeline.filtered_speedup(r, 55).unwrap_or(1.0))
        .collect();
    let geo_ours = metrics::geomean_speedup(&ours);
    assert!(
        geo_ours > geo_archive,
        "mini µC+SOL ({geo_ours:.2}) should beat the archive ({geo_archive:.2})"
    );
}
