//! Sweep-engine golden tests (ADR-005 acceptance).
//!
//! `repro sweep` claims that all 72 fig8/fig9 scheduler policies can be
//! replayed from ONE exhausted session pass, field-for-field identical to
//! re-driving sessions once per policy (`repro replay schedule` 72
//! times), and that the single pass issues at most 1/72 of the per-policy
//! evaluator calls. These tests pin both claims end to end:
//!
//! * every `ReplayResult` of the grid equals the realized online run of
//!   the same policy — stop indices, tokens, truncated `RunLog`s, and
//!   filtered geomeans, exactly;
//! * the sweep's exhausted pass is bit-identical at `--jobs 1` and
//!   `--jobs 4`;
//! * a [`TraceMonitor`]-counted strict replay shows
//!   `sweep_calls * 72 <= per_policy_calls` on the fig8 grid.

use ucutlass_repro::agent::controller::{ControllerKind, VariantSpec};
use ucutlass_repro::agent::ModelTier;
use ucutlass_repro::eval::{OwnedAnalytic, RecordingEvaluator, TraceEvaluator};
use ucutlass_repro::experiments::runner::run_variant;
use ucutlass_repro::experiments::Bench;
use ucutlass_repro::integrity::IntegrityPipeline;
use ucutlass_repro::scheduler::{self, Policy};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("ucutlass_sweep_{name}_{}.jsonl", std::process::id()))
}

/// The old per-policy path: what `repro replay schedule` executed for one
/// policy before the sweep engine existed (one online policy run; its
/// fixed reference is the policy-independent exhausted pass).
fn per_policy_online(
    env: &ucutlass_repro::agent::controller::Env,
    spec: &VariantSpec,
    seed: u64,
    policy: &Policy,
    jobs: usize,
) -> scheduler::OnlineRun {
    scheduler::run_online(env, spec, seed, policy, jobs)
}

#[test]
fn sweep_equals_per_policy_replay() {
    // the ISSUE-named golden: one exhausted pass + offline grid must be
    // field-for-field identical to running every policy online, for the
    // schedule-shaped orchestrated variant `repro schedule` drives
    let bench = Bench::new();
    let env = bench.env();
    let pipeline = IntegrityPipeline::default();
    let seed = 777;
    let spec = VariantSpec::new(ControllerKind::OrchestratedSol, true, ModelTier::Mini);

    let run1 = scheduler::sweep_sessions(&env, &spec, seed, 1, &pipeline, seed);
    let run4 = scheduler::sweep_sessions(&env, &spec, seed, 4, &pipeline, seed);
    // the exhausted pass (and hence every derived policy outcome) is
    // bit-identical at any job count
    assert_eq!(run1.log, run4.log, "--jobs 1 and --jobs 4 must agree exactly");
    let grid = scheduler::policy_grid();
    assert_eq!(run1.sweep.results.len(), 72);
    for (a, b) in run1.sweep.results.iter().zip(&run4.sweep.results) {
        assert_eq!(a.attempts_used, b.attempts_used);
        assert_eq!(a.tokens_used, b.tokens_used);
        assert_eq!(a.geomean, b.geomean);
    }

    // full grid vs the realized online runs (driven at --jobs 4; online
    // jobs-independence itself is pinned by the scheduler determinism
    // tests and re-checked on a subsample below)
    for (p, r) in grid.iter().zip(&run4.sweep.results) {
        let online = per_policy_online(&env, &spec, seed, p, 4);
        assert_eq!(r.attempts_used, online.attempts_used, "stops: {}", p.label());
        assert_eq!(r.tokens_used, online.tokens_used, "tokens: {}", p.label());
        let out = run4.outcome(p);
        assert_eq!(
            out.log.runs, online.log.runs,
            "truncated log must equal the online log field-for-field: {}",
            p.label()
        );
        assert_eq!(out.attempts_total(), online.attempts_total());
        assert_eq!(out.stopped_early(), online.stopped_early());
        assert_eq!(out.tokens_used, online.tokens_used);
        assert_eq!(
            pipeline.filtered_geomean(&out.log, seed),
            pipeline.filtered_geomean(&online.log, seed),
            "reported geomean must be bitwise equal: {}",
            p.label()
        );
        assert_eq!(
            out.token_savings(),
            online.token_savings_vs(&run4.log),
            "reported savings must be bitwise equal: {}",
            p.label()
        );
    }
    // subsample at --jobs 1 (covers the serial round-robin online path)
    for p in grid.iter().step_by(9) {
        let online = per_policy_online(&env, &spec, seed, p, 1);
        let out = run1.outcome(p);
        assert_eq!(out.log.runs, online.log.runs, "jobs=1: {}", p.label());
    }
}

#[test]
fn sweep_issues_at_most_one_72th_of_per_policy_evaluator_calls() {
    // TraceMonitor-counted acceptance bound on the fig8 grid: the sweep's
    // one exhausted pass must cost <= 1/72 of the evaluator calls the
    // per-policy path (online policy run + fixed reference, per policy)
    // issues against the same trace
    let path = tmp("calls");
    let pipeline = IntegrityPipeline::default();
    let seed = 41;
    let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mini);

    // record the exhausted pass once (live analytic behind the recorder)
    {
        let mut bench = Bench::new();
        let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &path).unwrap();
        let mon = rec.monitor();
        bench.set_oracle(Box::new(rec));
        let env = bench.env();
        let _ = scheduler::sweep_sessions(&env, &spec, seed, 2, &pipeline, seed);
        drop(bench);
        assert!(mon.recorded() > 0);
        assert_eq!(mon.io_error(), None);
    }

    // single-pass sweep, strictly from the trace
    let sweep_calls = {
        let mut bench = Bench::new();
        let trace = TraceEvaluator::load(&path).unwrap();
        let mon = trace.monitor();
        bench.set_oracle(Box::new(trace));
        let env = bench.env();
        let run = scheduler::sweep_sessions(&env, &spec, seed, 2, &pipeline, seed);
        assert_eq!(run.sweep.results.len(), 72);
        assert_eq!(mon.misses(), 0, "first miss: {:?}", mon.first_miss());
        assert!(mon.served() > 0, "the sweep must actually consult the trace");
        mon.served()
    };

    // per-policy path: 72 × (online policy run + fixed reference run)
    let per_policy_calls = {
        let mut bench = Bench::new();
        let trace = TraceEvaluator::load(&path).unwrap();
        let mon = trace.monitor();
        bench.set_oracle(Box::new(trace));
        let env = bench.env();
        for p in scheduler::policy_grid() {
            let _ = scheduler::run_online(&env, &spec, seed, &p, 2);
            let _ = scheduler::run_online(&env, &spec, seed, &Policy::fixed(), 2);
        }
        assert_eq!(mon.misses(), 0, "first miss: {:?}", mon.first_miss());
        mon.served()
    };

    assert!(
        sweep_calls * 72 <= per_policy_calls,
        "sweep must issue <= 1/72 of the per-policy evaluator calls: \
         sweep {sweep_calls}, per-policy {per_policy_calls}"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sweep_sessions_agree_with_run_variant_grid_for_independent_variants() {
    // for per-problem-independent variants the exhausted session pass IS
    // the classic run_variant log, so the offline grid fig8/fig9 computes
    // over `ExpCtx` logs and the grid `repro sweep` computes from its one
    // session pass coincide exactly
    let bench = Bench::new();
    let env = bench.env();
    let pipeline = IntegrityPipeline::default();
    let seed = 31;
    let spec = VariantSpec::new(ControllerKind::InPromptSol, true, ModelTier::Mid);

    let log = run_variant(&bench, &spec, seed, None);
    let figures_grid = scheduler::PolicySweep::over(&log, &pipeline, seed);
    let run = scheduler::sweep_sessions(&env, &spec, seed, 1, &pipeline, seed);
    assert_eq!(run.log.runs, log.runs, "one exhausted session pass == run_variant");
    for (a, b) in figures_grid.results.iter().zip(&run.sweep.results) {
        assert_eq!(a.attempts_used, b.attempts_used);
        assert_eq!(a.tokens_used, b.tokens_used);
        assert_eq!(a.geomean, b.geomean);
        assert_eq!(a.geomean_fixed, b.geomean_fixed);
    }
}

#[test]
fn sweep_strict_trace_replay_runs_with_zero_live_evaluations() {
    // the ROADMAP promise: replay all 72 policies against one trace in a
    // single pass — with the analytic backend fully disabled
    let path = tmp("offline");
    let pipeline = IntegrityPipeline::default();
    let seed = 9;
    let spec = VariantSpec::new(ControllerKind::Mi, true, ModelTier::Mini);

    let reference = {
        let mut bench = Bench::new();
        let rec = RecordingEvaluator::create(OwnedAnalytic::new(), &path).unwrap();
        bench.set_oracle(Box::new(rec));
        let env = bench.env();
        scheduler::sweep_sessions(&env, &spec, seed, 1, &pipeline, seed)
    };

    let mut bench = Bench::new();
    let trace = TraceEvaluator::load(&path).unwrap();
    let mon = trace.monitor();
    bench.set_oracle(Box::new(trace));
    let env = bench.env();
    let replayed = scheduler::sweep_sessions(&env, &spec, seed, 4, &pipeline, seed);
    assert_eq!(mon.misses(), 0, "strict replay must cover the whole sweep");
    assert!(mon.check().is_ok());
    assert_eq!(replayed.log, reference.log, "replayed pass must be field-for-field exact");
    for (a, b) in reference.sweep.results.iter().zip(&replayed.sweep.results) {
        assert_eq!(a.attempts_used, b.attempts_used);
        assert_eq!(a.tokens_used, b.tokens_used);
        assert_eq!(a.geomean, b.geomean);
    }
    let _ = std::fs::remove_file(&path);
}
