"""AOT lowering: JAX problem graphs → HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which the published xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/gen_hlo.py).

Run via ``make artifacts``. Python never runs on the request path: the Rust
coordinator loads these files once and executes them via PJRT.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
from typing import Callable, List

import jax
from jax._src.lib import xla_client as xc

from .model import PROBLEMS, Problem

MANIFEST_VERSION = 2


def to_hlo_text(fn: Callable, specs: List[jax.ShapeDtypeStruct]) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_tag(dtype: str) -> str:
    return {"float32": "f32", "bfloat16": "bf16", "float16": "f16"}.get(dtype, dtype)


def emit(out_dir: str, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": MANIFEST_VERSION, "problems": {}}
    n_files = 0
    for pname, prob in sorted(PROBLEMS.items()):
        specs = [s.sds() for s in prob.inputs]
        entry = {
            "kb_id": prob.kb_id,
            "inputs": [{"shape": list(s.shape), "dtype": _dtype_tag(s.dtype)}
                       for s in prob.inputs],
            "rtol": prob.rtol,
            "atol": prob.atol,
            "variants": {},
        }
        ref_path = f"{pname}__ref.hlo.txt"
        text = to_hlo_text(prob.reference, specs)
        with open(os.path.join(out_dir, ref_path), "w") as f:
            f.write(text)
        entry["reference"] = ref_path
        n_files += 1
        for vname, vfn in sorted(prob.variants.items()):
            vpath = f"{pname}__{vname}.hlo.txt"
            text = to_hlo_text(vfn, specs)
            with open(os.path.join(out_dir, vpath), "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            entry["variants"][vname] = {"path": vpath, "sha256_16": digest}
            n_files += 1
            if verbose:
                print(f"  {vpath}  ({len(text)} chars)")
        manifest["problems"][pname] = entry
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"wrote {n_files} HLO artifacts + manifest to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output dir (or a single .hlo.txt sentinel path)")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args()
    out = args.out
    # Makefile passes artifacts/model.hlo.txt as the stamp target; treat its
    # directory as the artifact dir and write a stamp file at the end.
    stamp = None
    if out.endswith(".hlo.txt") or out.endswith(".stamp"):
        stamp = out
        out = os.path.dirname(out) or "."
    emit(out, verbose=not args.quiet)
    if stamp is not None:
        with open(stamp, "w") as f:
            f.write("aot artifacts stamp\n")


if __name__ == "__main__":
    main()
