"""L2: JAX problem graphs for the AOT variant library.

Each :class:`Problem` describes one compute graph from our KernelBench
subset (Appendix A.3 of the paper), with

  * ``inputs``      — example input specs (shape, dtype),
  * ``reference``   — a pure-jnp oracle function (from kernels.ref),
  * ``variants``    — named candidate implementations backed by the L1
                      Pallas kernels, keyed by a µCUTLASS-style variant id
                      (tile shape × dtype × epilogue).

`aot.py` lowers reference + every variant to HLO text; the Rust runtime
(`rust/src/runtime/`) executes candidate and reference on identical inputs
and asserts allclose — this is the on-request-path correctness check for
kernels the agent selects.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import (GemmConfig, attention, batched_gemm, cumsum, gemm,
                      layernorm, rmsnorm, softmax)
from .kernels import ref as R


@dataclass(frozen=True)
class InputSpec:
    shape: Tuple[int, ...]
    dtype: str = "float32"

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))


@dataclass
class Problem:
    name: str
    kb_id: str                      # KernelBench problem this maps to, e.g. "L2-76"
    inputs: List[InputSpec]
    reference: Callable
    variants: Dict[str, Callable] = field(default_factory=dict)
    rtol: float = 1e-4
    atol: float = 1e-4


def _gemm_variants(epilogue: Tuple = (), tiles: Sequence[Tuple[int, int, int]] = (
        (32, 32, 32), (64, 64, 32), (64, 64, 64)), bf16: bool = True,
        aux_names: Sequence[str] = ()) -> Dict[str, Callable]:
    """Candidate set for one GEMM-family problem: tile sweep + one bf16-input
    variant on the largest tile (the reduced-precision lever SOL's FP16
    augmentation reasons about)."""
    out: Dict[str, Callable] = {}

    def make(cfg: GemmConfig):
        def fn(x, y, *aux_vals):
            aux = dict(zip(aux_names, aux_vals))
            return (gemm(x, y, cfg, aux=aux),)
        return fn

    for (bm, bn, bk) in tiles:
        cfg = GemmConfig(block_m=bm, block_n=bn, block_k=bk, epilogue=tuple(epilogue))
        out[f"t{bm}x{bn}x{bk}_fp32"] = make(cfg)
    if bf16:
        bm, bn, bk = tiles[-1]
        cfg = GemmConfig(block_m=bm, block_n=bn, block_k=bk,
                         in_dtype="bfloat16", epilogue=tuple(epilogue))
        out[f"t{bm}x{bn}x{bk}_bf16"] = make(cfg)
    return out


def _gemm_ref(epilogue: Tuple = (), aux_names: Sequence[str] = ()) -> Callable:
    cfg = GemmConfig(epilogue=tuple(epilogue))

    def fn(x, y, *aux_vals):
        aux = dict(zip(aux_names, aux_vals))
        return (R.gemm_ref(x, y, cfg, aux=aux),)
    return fn


def build_problems() -> Dict[str, Problem]:
    """The AOT problem registry. Shapes are laptop-scale stand-ins for the
    KernelBench originals (e.g. 4096³ GEMM → 256³); the SOL/perf analysis in
    Rust uses the *paper's* shapes — artifacts exist to prove numerics."""
    P: Dict[str, Problem] = {}
    f32 = "float32"

    # --- L1-1: square GEMM ------------------------------------------------
    P["gemm_square"] = Problem(
        name="gemm_square", kb_id="L1-1",
        inputs=[InputSpec((256, 256)), InputSpec((256, 256))],
        reference=_gemm_ref(),
        variants=_gemm_variants(tiles=((32, 32, 32), (64, 64, 32), (64, 64, 64),
                                       (128, 128, 32))),
    )

    # --- L1-9: tall-skinny GEMM ------------------------------------------
    P["gemm_tall_skinny"] = Problem(
        name="gemm_tall_skinny", kb_id="L1-9",
        inputs=[InputSpec((512, 64)), InputSpec((64, 128))],
        reference=_gemm_ref(),
        variants=_gemm_variants(tiles=((64, 32, 32), (128, 64, 32), (64, 64, 64))),
    )

    # --- L2-76: GEMM + bias + ReLU ----------------------------------------
    epi = (("bias", {}), ("relu", {}))
    P["gemm_bias_relu"] = Problem(
        name="gemm_bias_relu", kb_id="L2-76",
        inputs=[InputSpec((256, 128)), InputSpec((128, 256)), InputSpec((256,))],
        reference=_gemm_ref(epi, aux_names=("bias",)),
        variants=_gemm_variants(epi, aux_names=("bias",)),
    )

    # --- L2-86: GEMM + divide + GELU --------------------------------------
    epi = (("divide", {"value": 2.0}), ("gelu", {}))
    P["gemm_divide_gelu"] = Problem(
        name="gemm_divide_gelu", kb_id="L2-86",
        inputs=[InputSpec((256, 128)), InputSpec((128, 256))],
        reference=_gemm_ref(epi),
        variants=_gemm_variants(epi),
    )

    # --- L2-59: GEMM + SiLU + scale ---------------------------------------
    epi = (("silu", {}), ("scale", {"value": 1.5}))
    P["gemm_silu_scale"] = Problem(
        name="gemm_silu_scale", kb_id="L2-59",
        inputs=[InputSpec((256, 128)), InputSpec((128, 256))],
        reference=_gemm_ref(epi),
        variants=_gemm_variants(epi),
    )

    # --- L2-70: GEMM + sigmoid gate + residual add -------------------------
    def _gate_residual_candidate(cfg: GemmConfig):
        def fn(x, y, residual):
            g = gemm(x, y, cfg)
            return (jax.nn.sigmoid(g) * g + residual,)
        return fn

    def _gate_residual_ref(x, y, residual):
        g = R.gemm_ref(x, y, GemmConfig())
        return (jax.nn.sigmoid(g) * g + residual,)

    P["gemm_sigmoid_residual"] = Problem(
        name="gemm_sigmoid_residual", kb_id="L2-70",
        inputs=[InputSpec((256, 128)), InputSpec((128, 256)), InputSpec((256, 256))],
        reference=_gate_residual_ref,
        variants={
            f"t{bm}x{bn}x{bk}_fp32": _gate_residual_candidate(
                GemmConfig(block_m=bm, block_n=bn, block_k=bk))
            for (bm, bn, bk) in ((32, 32, 32), (64, 64, 32), (64, 64, 64))
        },
    )

    # --- L1-23: softmax -----------------------------------------------------
    P["softmax"] = Problem(
        name="softmax", kb_id="L1-23",
        inputs=[InputSpec((256, 512))],
        reference=lambda x: (R.softmax_ref(x),),
        variants={
            f"rows{br}": (lambda br: (lambda x: (softmax(x, block_rows=br),)))(br)
            for br in (8, 16, 32)
        },
    )

    # --- L1-36: RMSNorm -----------------------------------------------------
    P["rmsnorm"] = Problem(
        name="rmsnorm", kb_id="L1-36",
        inputs=[InputSpec((256, 512)), InputSpec((512,))],
        reference=lambda x, w: (R.rmsnorm_ref(x, w),),
        variants={
            f"rows{br}": (lambda br: (lambda x, w: (rmsnorm(x, w, block_rows=br),)))(br)
            for br in (8, 16, 32)
        },
    )

    # --- L1-40: LayerNorm ---------------------------------------------------
    P["layernorm"] = Problem(
        name="layernorm", kb_id="L1-40",
        inputs=[InputSpec((256, 512)), InputSpec((512,)), InputSpec((512,))],
        reference=lambda x, w, b: (R.layernorm_ref(x, w, b),),
        variants={
            f"rows{br}": (lambda br: (lambda x, w, b: (layernorm(x, w, b, block_rows=br),)))(br)
            for br in (8, 16, 32)
        },
    )

    # --- L1-89: cumsum -------------------------------------------------------
    P["cumsum"] = Problem(
        name="cumsum", kb_id="L1-89",
        inputs=[InputSpec((128, 512))],
        reference=lambda x: (R.cumsum_ref(x),),
        variants={
            f"rows{br}": (lambda br: (lambda x: (cumsum(x, block_rows=br),)))(br)
            for br in (8, 16)
        },
    )

    # --- L1-97: scaled dot-product attention --------------------------------
    attn_in = [InputSpec((2, 2, 128, 64)) for _ in range(3)]
    P["attention"] = Problem(
        name="attention", kb_id="L1-97",
        inputs=list(attn_in),
        reference=lambda q, k, v: (R.attention_ref(q, k, v),),
        variants={
            f"bq{bq}": (lambda bq: (lambda q, k, v: (attention(q, k, v, block_q=bq),)))(bq)
            for bq in (16, 32, 64)
        },
        rtol=1e-3, atol=1e-3,
    )

    # --- L3-43: causal attention ---------------------------------------------
    P["causal_attention"] = Problem(
        name="causal_attention", kb_id="L3-43",
        inputs=list(attn_in),
        reference=lambda q, k, v: (R.attention_ref(q, k, v, causal=True),),
        variants={
            f"bq{bq}": (lambda bq: (lambda q, k, v: (attention(q, k, v, causal=True, block_q=bq),)))(bq)
            for bq in (16, 32, 64)
        },
        rtol=1e-3, atol=1e-3,
    )

    # --- L3-1: MLP block (gemm >> relu, gemm) — the pipeline(...) analogue ---
    def _mlp_candidate(cfg1: GemmConfig, cfg2: GemmConfig):
        def fn(x, w1, b1, w2):
            h = gemm(x, w1, cfg1, aux={"bias": b1})
            return (gemm(h, w2, cfg2),)
        return fn

    def _mlp_ref(x, w1, b1, w2):
        h = R.gemm_ref(x, w1, GemmConfig(epilogue=(("bias", {}), ("relu", {}))),
                       aux={"bias": b1})
        return (R.gemm_ref(h, w2, GemmConfig()),)

    mlp_epi = (("bias", {}), ("relu", {}))
    P["mlp_block"] = Problem(
        name="mlp_block", kb_id="L3-1",
        inputs=[InputSpec((128, 256)), InputSpec((256, 512)), InputSpec((512,)),
                InputSpec((512, 128))],
        reference=_mlp_ref,
        variants={
            f"t{bm}x{bn}x{bk}": _mlp_candidate(
                GemmConfig(block_m=bm, block_n=bn, block_k=bk, epilogue=mlp_epi),
                GemmConfig(block_m=bm, block_n=bn, block_k=bk))
            for (bm, bn, bk) in ((32, 32, 32), (64, 64, 64), (64, 128, 32))
        },
        # two chained GEMMs amplify accumulation-order differences; outputs
        # are O(300) so 1e-3 abs is still ~1e-6 relative
        rtol=1e-3, atol=2e-3,
    )

    # --- L1-3: batched matmul -------------------------------------------------
    def _bmm_candidate(cfg: GemmConfig):
        def fn(x, y):
            return (batched_gemm(x, y, cfg),)
        return fn

    P["batched_gemm"] = Problem(
        name="batched_gemm", kb_id="L1-3",
        inputs=[InputSpec((4, 128, 64)), InputSpec((4, 64, 128))],
        reference=lambda x, y: (R.batched_gemm_ref(x, y, GemmConfig()),),
        variants={
            f"t{bm}x{bn}x{bk}_fp32": _bmm_candidate(
                GemmConfig(block_m=bm, block_n=bn, block_k=bk))
            for (bm, bn, bk) in ((32, 32, 32), (64, 64, 32), (64, 64, 64))
        },
    )

    return P


PROBLEMS = build_problems()
