"""L1 Pallas kernel: scaled dot-product attention (KernelBench L1-97, L3-43).

Flash-style row-blocked attention: for each query block the full K/V live in
VMEM (sequence lengths in our scaled problems are small); the softmax is
computed stably in fp32. Causal masking supports the decoder problems.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_2d(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
             causal: bool, block_q: int) -> jnp.ndarray:
    s, d = q.shape
    if s % block_q != 0:
        raise ValueError(f"seq={s} not divisible by block_q={block_q}")
    scale = 1.0 / math.sqrt(d)

    def kernel(q_ref, k_ref, v_ref, o_ref):
        qb = q_ref[...].astype(jnp.float32) * scale
        kb = k_ref[...].astype(jnp.float32)
        vb = v_ref[...].astype(jnp.float32)
        logits = qb @ kb.T  # (block_q, s)
        if causal:
            qi = pl.program_id(0) * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, s), 0)
            kj = jax.lax.broadcasted_iota(jnp.int32, (block_q, s), 1)
            logits = jnp.where(kj <= qi, logits, -jnp.inf)
        logits = logits - jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp(logits)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        o_ref[...] = (p @ vb).astype(q_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(s // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
            pl.BlockSpec((s, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        interpret=True,
    )(q, k, v)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              causal: bool = False, block_q: int = 32) -> jnp.ndarray:
    """Attention over (..., seq, head_dim); leading dims are vmapped."""
    fn = functools.partial(_attn_2d, causal=causal, block_q=block_q)
    for _ in range(q.ndim - 2):
        fn = jax.vmap(fn)
    return fn(q, k, v)
