"""L1 Pallas kernel: tiled GEMM with fused µCUTLASS-style epilogue.

TPU-adapted expression of the paper's CUTLASS design space (DESIGN.md
§Hardware-Adaptation): BlockSpec tiles play the role of threadblock tiles,
the VMEM-resident accumulator scratch plays the role of the SMEM-staged
accumulator, and the (m, n, k) grid iteration order plays the role of the
tile scheduler. Epilogue chains are fused onto the accumulator tile before
the single store to HBM — the analogue of CUTLASS's Epilogue Visitor Tree.

interpret=True throughout: real-TPU lowering emits Mosaic custom-calls the
CPU PJRT plugin cannot run (see /opt/xla-example/README.md).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .epilogues import EpilogueOp, apply_epilogue_chain, chain_aux_names


@dataclass(frozen=True)
class GemmConfig:
    """Mirror of the µCUTLASS kernel-configuration IR that reaches L1.

    block_{m,n,k}   — threadblock-tile analogue (must divide M/N/K here).
    acc_dtype       — accumulator dtype (fp32 accumulation is the default,
                      as in CUTLASS's ``.with_dtype(acc=...)``).
    epilogue        — fused ``>>`` chain applied to the accumulator tile.
    """
    block_m: int = 64
    block_n: int = 64
    block_k: int = 64
    in_dtype: str = "float32"
    acc_dtype: str = "float32"
    out_dtype: str = "float32"
    epilogue: Tuple[EpilogueOp, ...] = field(default_factory=tuple)


def _check_divisible(dim: int, block: int, name: str) -> None:
    if dim % block != 0:
        raise ValueError(f"{name}={dim} not divisible by block {block}")


def gemm(x: jnp.ndarray, y: jnp.ndarray, cfg: GemmConfig,
         aux: Dict[str, jnp.ndarray] | None = None) -> jnp.ndarray:
    """C = epilogue(x @ y) with an (m, n, k)-gridded Pallas kernel."""
    aux = aux or {}
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm, bn, bk = cfg.block_m, cfg.block_n, cfg.block_k
    _check_divisible(m, bm, "M")
    _check_divisible(n, bn, "N")
    _check_divisible(k, bk, "K")
    grid = (m // bm, n // bn, k // bk)
    nk = grid[2]
    acc_dtype = jnp.dtype(cfg.acc_dtype)
    out_dtype = jnp.dtype(cfg.out_dtype)
    aux_names = chain_aux_names(cfg.epilogue)

    # BlockSpecs for aux operands: bias/col_scale vary along n; row_scale
    # along m; residual along (m, n).
    aux_specs = []
    aux_vals = []
    for name in aux_names:
        val = aux[name]
        aux_vals.append(val)
        if name in ("bias", "col_scale"):
            aux_specs.append(pl.BlockSpec((bn,), lambda i, j, kk: (j,)))
        elif name == "row_scale":
            aux_specs.append(pl.BlockSpec((bm,), lambda i, j, kk: (i,)))
        elif name == "residual":
            aux_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        else:  # pragma: no cover - guarded by chain_aux_names
            raise ValueError(name)

    def kernel(x_ref, y_ref, *rest):
        *aux_refs, o_ref, acc_ref = rest
        kk = pl.program_id(2)

        @pl.when(kk == 0)
        def _zero():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        xt = x_ref[...].astype(acc_dtype)
        yt = y_ref[...].astype(acc_dtype)
        acc_ref[...] += jnp.dot(xt, yt, preferred_element_type=acc_dtype)

        @pl.when(kk == nk - 1)
        def _store():
            tile = acc_ref[...]
            tile_aux = {}
            for aname, aref in zip(aux_names, aux_refs):
                aval = aref[...].astype(acc_dtype)
                tile_aux[aname] = aval
            tile = apply_epilogue_chain(tile, cfg.epilogue, tile_aux)
            o_ref[...] = tile.astype(out_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            *aux_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        # fp32 accumulator tile resident in VMEM across the k loop — the
        # SMEM-staged accumulator analogue of the CUTLASS mainloop.
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=True,
    )(x, y, *aux_vals)


def batched_gemm(x: jnp.ndarray, y: jnp.ndarray, cfg: GemmConfig,
                 aux: Dict[str, jnp.ndarray] | None = None) -> jnp.ndarray:
    """Batched GEMM: vmap of the tiled kernel over the leading batch dim."""
    fn = functools.partial(gemm, cfg=cfg, aux=aux)
    return jax.vmap(fn)(x, y)
