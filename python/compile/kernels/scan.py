"""L1 Pallas kernels: prefix scans (KernelBench L1-89/90/91/92 analogues).

Row-blocked cumulative sum/product along the last dim, with exclusive and
reverse variants — the scan primitives SSM/linear-attention recurrences use.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rowblock_call(kernel, x: jnp.ndarray, block_rows: int):
    m, n = x.shape
    if m % block_rows != 0:
        raise ValueError(f"rows={m} not divisible by block_rows={block_rows}")
    return pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)


def cumsum(x: jnp.ndarray, block_rows: int = 16) -> jnp.ndarray:
    def kernel(x_ref, o_ref):
        o_ref[...] = jnp.cumsum(x_ref[...], axis=-1)
    return _rowblock_call(kernel, x, block_rows)


def cumprod(x: jnp.ndarray, block_rows: int = 16) -> jnp.ndarray:
    def kernel(x_ref, o_ref):
        o_ref[...] = jnp.cumprod(x_ref[...], axis=-1)
    return _rowblock_call(kernel, x, block_rows)


def exclusive_cumsum(x: jnp.ndarray, block_rows: int = 16) -> jnp.ndarray:
    def kernel(x_ref, o_ref):
        c = jnp.cumsum(x_ref[...], axis=-1)
        o_ref[...] = c - x_ref[...]
    return _rowblock_call(kernel, x, block_rows)


def reverse_cumsum(x: jnp.ndarray, block_rows: int = 16) -> jnp.ndarray:
    def kernel(x_ref, o_ref):
        t = jnp.flip(x_ref[...], axis=-1)
        o_ref[...] = jnp.flip(jnp.cumsum(t, axis=-1), axis=-1)
    return _rowblock_call(kernel, x, block_rows)
