"""L1 Pallas kernels: RMSNorm and LayerNorm (KernelBench L1-36 / L1-40).

Row-blocked: each grid step normalizes a block of rows whose feature dim is
fully VMEM-resident, with the per-feature affine parameters broadcast in.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6,
            block_rows: int = 16) -> jnp.ndarray:
    m, n = x.shape
    if m % block_rows != 0:
        raise ValueError(f"rows={m} not divisible by block_rows={block_rows}")

    def kernel(x_ref, w_ref, o_ref):
        t = x_ref[...].astype(jnp.float32)
        ms = jnp.mean(t * t, axis=-1, keepdims=True)
        o_ref[...] = (t * jax.lax.rsqrt(ms + eps) * w_ref[...]).astype(x_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, weight)


def layernorm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5, block_rows: int = 16) -> jnp.ndarray:
    m, n = x.shape
    if m % block_rows != 0:
        raise ValueError(f"rows={m} not divisible by block_rows={block_rows}")

    def kernel(x_ref, w_ref, b_ref, o_ref):
        t = x_ref[...].astype(jnp.float32)
        mu = jnp.mean(t, axis=-1, keepdims=True)
        var = jnp.mean((t - mu) * (t - mu), axis=-1, keepdims=True)
        norm = (t - mu) * jax.lax.rsqrt(var + eps)
        o_ref[...] = (norm * w_ref[...] + b_ref[...]).astype(x_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, weight, bias)
