"""L1 Pallas kernel: numerically-stable row softmax.

One grid step per row-block; the full row lives in VMEM (rows in the 59
KernelBench problems we reproduce are ≤ a few K elements, well under the
VMEM budget documented in DESIGN.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def softmax(x: jnp.ndarray, block_rows: int = 16) -> jnp.ndarray:
    """Row-wise softmax over the last dim of a 2D array."""
    m, n = x.shape
    if m % block_rows != 0:
        raise ValueError(f"rows={m} not divisible by block_rows={block_rows}")

    def kernel(x_ref, o_ref):
        t = x_ref[...]
        t = t - jnp.max(t, axis=-1, keepdims=True)
        e = jnp.exp(t)
        o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)

    return pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)


def cross_entropy(logits: jnp.ndarray, targets_onehot: jnp.ndarray,
                  block_rows: int = 16) -> jnp.ndarray:
    """Mean cross-entropy loss from logits (KernelBench L1-95 analogue).

    The log-softmax runs as a Pallas kernel; the final mean reduction is a
    plain jnp reduction fused by XLA into the same HLO module.
    """
    m, n = logits.shape
    if m % block_rows != 0:
        raise ValueError(f"rows={m} not divisible by block_rows={block_rows}")

    def kernel(x_ref, t_ref, o_ref):
        x = x_ref[...]
        x = x - jnp.max(x, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(x), axis=-1, keepdims=True))
        logp = x - lse
        o_ref[...] = -jnp.sum(logp * t_ref[...], axis=-1, keepdims=True)

    per_row = pl.pallas_call(
        kernel,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), logits.dtype),
        interpret=True,
    )(logits, targets_onehot)
    return jnp.mean(per_row)
