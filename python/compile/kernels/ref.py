"""Pure-jnp correctness oracles for every L1 Pallas kernel.

These are the ground truth the pytest suite (and the Rust runtime, via the
`*_ref` HLO artifacts) compares candidates against. No Pallas here — plain
jnp only, so any agreement is between two independent code paths.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from .epilogues import apply_epilogue_chain
from .gemm import GemmConfig


def gemm_ref(x: jnp.ndarray, y: jnp.ndarray, cfg: GemmConfig,
             aux: Dict[str, jnp.ndarray] | None = None) -> jnp.ndarray:
    acc = jnp.dot(x.astype(cfg.acc_dtype), y.astype(cfg.acc_dtype),
                  preferred_element_type=jnp.dtype(cfg.acc_dtype))
    aux32 = {k: v.astype(cfg.acc_dtype) for k, v in (aux or {}).items()}
    return apply_epilogue_chain(acc, cfg.epilogue, aux32).astype(cfg.out_dtype)


def batched_gemm_ref(x, y, cfg: GemmConfig, aux=None):
    return jax.vmap(lambda a, b: gemm_ref(a, b, cfg, aux))(x, y)


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.softmax(x, axis=-1)


def cross_entropy_ref(logits: jnp.ndarray, targets_onehot: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(logp * targets_onehot, axis=-1))


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    t = x.astype(jnp.float32)
    ms = jnp.mean(t * t, axis=-1, keepdims=True)
    return (t * jax.lax.rsqrt(ms + eps) * weight).astype(x.dtype)


def layernorm_ref(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
                  eps: float = 1e-5) -> jnp.ndarray:
    t = x.astype(jnp.float32)
    mu = jnp.mean(t, axis=-1, keepdims=True)
    var = jnp.mean((t - mu) ** 2, axis=-1, keepdims=True)
    return ((t - mu) * jax.lax.rsqrt(var + eps) * weight + bias).astype(x.dtype)


def cumsum_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(x, axis=-1)


def cumprod_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumprod(x, axis=-1)


def exclusive_cumsum_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(x, axis=-1) - x


def reverse_cumsum_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.flip(jnp.cumsum(jnp.flip(x, axis=-1), axis=-1), axis=-1)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = False) -> jnp.ndarray:
    d = q.shape[-1]
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(d)
    if causal:
        s = q.shape[-2]
        mask = jnp.tril(jnp.ones((s, logits.shape[-1]), bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32)).astype(q.dtype)
