"""Shared epilogue math for µCUTLASS-style fused epilogues.

The same formulas are used by the Pallas kernels (applied in-kernel on the
accumulator tile, L1) and by the pure-jnp reference oracle (applied on the
full matmul result, ref.py). Keeping one definition guarantees the candidate
and the oracle disagree only through tiling/accumulation order, never
through activation formulas.

Epilogue chains mirror the µCUTLASS ``>>`` operator: a list of (name, params)
pairs applied left-to-right to the accumulator.
"""
from __future__ import annotations

from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

EpilogueOp = Tuple[str, Dict[str, Any]]


def _erf_gelu(x):
    # tanh-approximation GELU (the CUTLASS GELU_taylor EVT node). We avoid
    # the erf form deliberately: jax >= 0.8 lowers jax.lax.erf to a native
    # `erf` HLO opcode that the xla_extension 0.5.1 text parser (the Rust
    # runtime's XLA) does not know. The tanh form lowers to basic ops.
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def apply_epilogue_op(x: jnp.ndarray, name: str, params: Dict[str, Any],
                      aux: Dict[str, jnp.ndarray] | None = None) -> jnp.ndarray:
    """Apply one epilogue op. ``aux`` holds broadcast operands (bias, scales)."""
    aux = aux or {}
    if name == "relu":
        return jnp.maximum(x, 0.0)
    if name == "gelu":
        return _erf_gelu(x)
    if name == "silu":
        return x * jax.nn.sigmoid(x)
    if name == "sigmoid":
        return jax.nn.sigmoid(x)
    if name == "tanh":
        return jnp.tanh(x)
    if name == "mish":
        return x * jnp.tanh(jax.nn.softplus(x))
    if name == "hardswish":
        return x * jnp.clip(x + 3.0, 0.0, 6.0) / 6.0
    if name == "leaky_relu":
        alpha = params.get("alpha", 0.01)
        return jnp.where(x >= 0, x, alpha * x)
    if name == "elu":
        alpha = params.get("alpha", 1.0)
        return jnp.where(x >= 0, x, alpha * (jnp.exp(x) - 1.0))
    if name in ("clip", "clamp"):
        return jnp.clip(x, params.get("lo", 0.0), params.get("hi", 1.0))
    if name == "scale":
        return x * params.get("value", 1.0)
    if name == "divide":
        return x / params.get("value", 1.0)
    if name == "bias":
        # bias over the last (column) dimension, shape (N,)
        return x + aux["bias"]
    if name == "per_row_scale":
        return x * aux["row_scale"][:, None]
    if name == "per_col_scale":
        return x * aux["col_scale"]
    if name == "add":
        # residual add, same shape as x
        return x + aux["residual"]
    raise ValueError(f"unknown epilogue op: {name}")


def apply_epilogue_chain(x: jnp.ndarray, chain: Sequence[EpilogueOp],
                         aux: Dict[str, jnp.ndarray] | None = None) -> jnp.ndarray:
    for name, params in chain:
        x = apply_epilogue_op(x, name, params, aux)
    return x


#: Which aux tensor (if any) each epilogue op consumes, keyed by op name.
EPILOGUE_AUX = {
    "bias": "bias",
    "per_row_scale": "row_scale",
    "per_col_scale": "col_scale",
    "add": "residual",
}


def chain_aux_names(chain: Sequence[EpilogueOp]) -> List[str]:
    """Aux operand names a chain requires, in chain order, deduplicated."""
    seen: List[str] = []
    for name, _ in chain:
        aux = EPILOGUE_AUX.get(name)
        if aux is not None and aux not in seen:
            seen.append(aux)
    return seen
