"""L1: Pallas kernels (build-time only) + pure-jnp reference oracles."""
from .attention import attention
from .epilogues import (EPILOGUE_AUX, apply_epilogue_chain, apply_epilogue_op,
                        chain_aux_names)
from .gemm import GemmConfig, batched_gemm, gemm
from .norm import layernorm, rmsnorm
from .scan import cumprod, cumsum, exclusive_cumsum, reverse_cumsum
from .softmax import cross_entropy, softmax

__all__ = [
    "attention", "apply_epilogue_chain", "apply_epilogue_op", "EPILOGUE_AUX",
    "chain_aux_names", "GemmConfig", "gemm", "batched_gemm", "layernorm",
    "rmsnorm", "cumsum", "cumprod", "exclusive_cumsum", "reverse_cumsum",
    "softmax", "cross_entropy",
]
