# Unit + hypothesis tests for the shared epilogue math: every op, chain
# composition order, and agreement with PyTorch-style reference formulas.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.epilogues import (EPILOGUE_AUX, apply_epilogue_chain,
                                       apply_epilogue_op, chain_aux_names)

jax.config.update("jax_platform_name", "cpu")

X = jnp.linspace(-5.0, 5.0, 101, dtype=jnp.float32).reshape(1, -1)


def test_relu():
    out = apply_epilogue_op(X, "relu", {})
    np.testing.assert_allclose(out, np.maximum(np.asarray(X), 0.0))


def test_sigmoid_range():
    out = np.asarray(apply_epilogue_op(X, "sigmoid", {}))
    assert out.min() > 0.0 and out.max() < 1.0
    np.testing.assert_allclose(out, 1.0 / (1.0 + np.exp(-np.asarray(X))), rtol=1e-6)


def test_gelu_matches_torch_tanh_approx():
    # torch.nn.functional.gelu(x, approximate="tanh")
    x = np.asarray(X, np.float64)
    ref = 0.5 * x * (1.0 + np.tanh(np.sqrt(2.0 / np.pi) * (x + 0.044715 * x ** 3)))
    np.testing.assert_allclose(apply_epilogue_op(X, "gelu", {}), ref, rtol=1e-5, atol=1e-6)


def test_silu():
    x = np.asarray(X)
    np.testing.assert_allclose(apply_epilogue_op(X, "silu", {}),
                               x / (1.0 + np.exp(-x)), rtol=1e-5, atol=1e-6)


def test_leaky_relu_alpha():
    out = np.asarray(apply_epilogue_op(X, "leaky_relu", {"alpha": 0.2}))
    x = np.asarray(X)
    np.testing.assert_allclose(out, np.where(x >= 0, x, 0.2 * x), rtol=1e-6)


def test_elu():
    out = np.asarray(apply_epilogue_op(X, "elu", {"alpha": 1.5}))
    x = np.asarray(X)
    np.testing.assert_allclose(out, np.where(x >= 0, x, 1.5 * (np.exp(x) - 1)),
                               rtol=1e-5, atol=1e-6)


def test_clip_bounds():
    out = np.asarray(apply_epilogue_op(X, "clip", {"lo": -1.0, "hi": 2.0}))
    assert out.min() >= -1.0 and out.max() <= 2.0


def test_hardswish_matches_definition():
    x = np.asarray(X)
    ref = x * np.clip(x + 3.0, 0.0, 6.0) / 6.0
    np.testing.assert_allclose(apply_epilogue_op(X, "hardswish", {}), ref, rtol=1e-6)


def test_mish():
    x = np.asarray(X, np.float64)
    ref = x * np.tanh(np.log1p(np.exp(x)))
    np.testing.assert_allclose(apply_epilogue_op(X, "mish", {}), ref, rtol=1e-4, atol=1e-5)


def test_scale_divide_inverse():
    a = apply_epilogue_op(X, "scale", {"value": 4.0})
    b = apply_epilogue_op(a, "divide", {"value": 4.0})
    np.testing.assert_allclose(b, X, rtol=1e-6)


def test_bias_broadcast():
    bias = jnp.arange(X.shape[1], dtype=jnp.float32)
    out = apply_epilogue_op(X, "bias", {}, aux={"bias": bias})
    np.testing.assert_allclose(out, np.asarray(X) + np.asarray(bias), rtol=1e-6)


def test_per_row_and_col_scale():
    x = jnp.ones((4, 6), jnp.float32)
    rs = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    cs = jnp.arange(1.0, 7.0, dtype=jnp.float32)
    out_r = np.asarray(apply_epilogue_op(x, "per_row_scale", {}, aux={"row_scale": rs}))
    out_c = np.asarray(apply_epilogue_op(x, "per_col_scale", {}, aux={"col_scale": cs}))
    np.testing.assert_allclose(out_r[:, 0], [1, 2, 3, 4])
    np.testing.assert_allclose(out_c[0], np.arange(1.0, 7.0))


def test_residual_add():
    r = jnp.full_like(X, 2.0)
    out = apply_epilogue_op(X, "add", {}, aux={"residual": r})
    np.testing.assert_allclose(out, np.asarray(X) + 2.0, rtol=1e-6)


def test_unknown_op_raises():
    with pytest.raises(ValueError, match="unknown epilogue op"):
        apply_epilogue_op(X, "not_an_op", {})


def test_chain_order_matters():
    chain_a = (("relu", {}), ("scale", {"value": -1.0}))
    chain_b = (("scale", {"value": -1.0}), ("relu", {}))
    a = np.asarray(apply_epilogue_chain(X, chain_a))
    b = np.asarray(apply_epilogue_chain(X, chain_b))
    assert not np.allclose(a, b), "left-to-right >> composition is order-sensitive"


def test_chain_aux_names_dedup_and_order():
    chain = (("bias", {}), ("relu", {}), ("add", {}), ("bias", {}))
    assert chain_aux_names(chain) == ["bias", "residual"]
    assert EPILOGUE_AUX["per_row_scale"] == "row_scale"


@settings(max_examples=30, deadline=None)
@given(
    ops=st.lists(
        st.sampled_from(["relu", "gelu", "silu", "sigmoid", "tanh", "hardswish"]),
        min_size=1, max_size=4),
    scale=st.floats(0.1, 10.0),
)
def test_chain_is_finite_and_composes(ops, scale):
    chain = tuple((o, {}) for o in ops) + (("scale", {"value": scale}),)
    out = np.asarray(apply_epilogue_chain(X, chain))
    assert np.all(np.isfinite(out))
    # composing manually must agree
    y = X
    for name, params in chain:
        y = apply_epilogue_op(y, name, params)
    np.testing.assert_allclose(out, y, rtol=1e-6)
