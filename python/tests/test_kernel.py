# pytest: Pallas kernels vs pure-jnp oracle — the CORE correctness signal.
# Hypothesis sweeps shapes/dtypes/epilogues; fixed-seed cases pin the exact
# configurations that ship as AOT artifacts.
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref as R
from compile.kernels.gemm import GemmConfig

jax.config.update("jax_platform_name", "cpu")

RNG = np.random.default_rng(1234)


def randn(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


# ---------------------------------------------------------------------------
# GEMM: tile sweep × epilogue chains
# ---------------------------------------------------------------------------

TILES = [(32, 32, 32), (64, 64, 32), (64, 32, 64), (128, 64, 32)]
EPILOGUES = [
    (),
    (("relu", {}),),
    (("gelu", {}),),
    (("silu", {}),),
    (("sigmoid", {}),),
    (("tanh", {}),),
    (("mish", {}),),
    (("hardswish", {}),),
    (("leaky_relu", {"alpha": 0.1}),),
    (("elu", {"alpha": 1.0}),),
    (("clamp", {"lo": -1.0, "hi": 1.0}),),
    (("scale", {"value": 0.5}),),
    (("divide", {"value": 2.0}),),
    (("scale", {"value": 2.0}), ("gelu", {})),
    (("silu", {}), ("scale", {"value": 1.5})),
]


@pytest.mark.parametrize("tile", TILES)
def test_gemm_tiles(tile):
    bm, bn, bk = tile
    m, n, k = bm * 2, bn * 2, bk * 3
    x, y = randn(m, k), randn(k, n)
    cfg = GemmConfig(block_m=bm, block_n=bn, block_k=bk)
    out = K.gemm(x, y, cfg)
    np.testing.assert_allclose(out, R.gemm_ref(x, y, cfg), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("epilogue", EPILOGUES, ids=lambda e: "+".join(n for n, _ in e) or "none")
def test_gemm_epilogues(epilogue):
    x, y = randn(64, 96), randn(96, 64)
    cfg = GemmConfig(block_m=32, block_n=32, block_k=32, epilogue=tuple(epilogue))
    out = K.gemm(x, y, cfg)
    np.testing.assert_allclose(out, R.gemm_ref(x, y, cfg), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("aux_op,aux_name,aux_shape", [
    ("bias", "bias", ("n",)),
    ("per_row_scale", "row_scale", ("m",)),
    ("per_col_scale", "col_scale", ("n",)),
    ("add", "residual", ("m", "n")),
])
def test_gemm_aux_epilogues(aux_op, aux_name, aux_shape):
    m, n, k = 64, 96, 64
    dims = {"m": m, "n": n}
    x, y = randn(m, k), randn(k, n)
    aux = {aux_name: randn(*[dims[d] for d in aux_shape])}
    cfg = GemmConfig(block_m=32, block_n=32, block_k=32,
                     epilogue=((aux_op, {}),))
    out = K.gemm(x, y, cfg, aux=aux)
    np.testing.assert_allclose(out, R.gemm_ref(x, y, cfg, aux=aux),
                               rtol=1e-4, atol=1e-4)


def test_gemm_bias_relu_chain():
    x, y, b = randn(128, 64), randn(64, 128), randn(128)
    cfg = GemmConfig(block_m=64, block_n=64, block_k=32,
                     epilogue=(("bias", {}), ("relu", {})))
    out = K.gemm(x, y, cfg, aux={"bias": b})
    np.testing.assert_allclose(out, R.gemm_ref(x, y, cfg, aux={"bias": b}),
                               rtol=1e-4, atol=1e-4)


def test_gemm_bf16_accumulate_fp32():
    x, y = randn(64, 64), randn(64, 64)
    cfg = GemmConfig(block_m=32, block_n=32, block_k=32, in_dtype="bfloat16")
    out = K.gemm(x, y, cfg)
    ref = R.gemm_ref(x, y, cfg)
    np.testing.assert_allclose(out, ref, rtol=1e-2, atol=1e-2)


def test_gemm_rejects_nondivisible():
    x, y = randn(60, 64), randn(64, 64)
    with pytest.raises(ValueError, match="not divisible"):
        K.gemm(x, y, GemmConfig(block_m=32, block_n=32, block_k=32))


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 4), ni=st.integers(1, 4), ki=st.integers(1, 4),
    tile=st.sampled_from([(16, 16, 16), (32, 32, 32), (32, 16, 32)]),
    epi=st.sampled_from([tuple(e) for e in EPILOGUES[:8]]),
)
def test_gemm_property(mi, ni, ki, tile, epi):
    bm, bn, bk = tile
    m, n, k = bm * mi, bn * ni, bk * ki
    rng = np.random.default_rng(m * 131 + n * 17 + k)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    cfg = GemmConfig(block_m=bm, block_n=bn, block_k=bk, epilogue=epi)
    np.testing.assert_allclose(K.gemm(x, y, cfg), R.gemm_ref(x, y, cfg),
                               rtol=1e-4, atol=1e-4)


def test_batched_gemm():
    x, y = randn(4, 64, 32), randn(4, 32, 64)
    cfg = GemmConfig(block_m=32, block_n=32, block_k=32)
    np.testing.assert_allclose(K.batched_gemm(x, y, cfg),
                               R.batched_gemm_ref(x, y, cfg),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Softmax / cross-entropy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,br", [((64, 128), 8), ((64, 128), 16),
                                      ((128, 17), 32), ((32, 512), 8)])
def test_softmax(shape, br):
    x = randn(*shape)
    np.testing.assert_allclose(K.softmax(x, block_rows=br), R.softmax_ref(x),
                               rtol=1e-5, atol=1e-6)


def test_softmax_extreme_values():
    x = jnp.asarray([[1e4, -1e4, 0.0, 1e4]] * 8, jnp.float32)
    out = K.softmax(x, block_rows=8)
    np.testing.assert_allclose(out, R.softmax_ref(x), rtol=1e-5, atol=1e-6)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_cross_entropy():
    logits = randn(64, 32)
    t = jax.nn.one_hot(jnp.asarray(RNG.integers(0, 32, 64)), 32)
    np.testing.assert_allclose(K.cross_entropy(logits, t),
                               R.cross_entropy_ref(logits, t),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(rows=st.sampled_from([8, 16, 32]), cols=st.integers(2, 200))
def test_softmax_property(rows, cols):
    rng = np.random.default_rng(rows * 1000 + cols)
    x = jnp.asarray(rng.standard_normal((rows * 2, cols)).astype(np.float32) * 10)
    out = K.softmax(x, block_rows=rows)
    np.testing.assert_allclose(out, R.softmax_ref(x), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("br", [8, 16, 32])
def test_rmsnorm(br):
    x, w = randn(64, 256), randn(256)
    np.testing.assert_allclose(K.rmsnorm(x, w, block_rows=br),
                               R.rmsnorm_ref(x, w), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("br", [8, 16, 32])
def test_layernorm(br):
    x, w, b = randn(64, 256), randn(256), randn(256)
    np.testing.assert_allclose(K.layernorm(x, w, b, block_rows=br),
                               R.layernorm_ref(x, w, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(cols=st.integers(4, 300), scale=st.floats(0.01, 100.0))
def test_rmsnorm_property(cols, scale):
    rng = np.random.default_rng(cols)
    x = jnp.asarray(rng.standard_normal((16, cols)).astype(np.float32) * scale)
    w = jnp.asarray(rng.standard_normal(cols).astype(np.float32))
    np.testing.assert_allclose(K.rmsnorm(x, w, block_rows=8),
                               R.rmsnorm_ref(x, w), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Scans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn,ref", [
    (K.cumsum, R.cumsum_ref), (K.cumprod, R.cumprod_ref),
    (K.exclusive_cumsum, R.exclusive_cumsum_ref),
    (K.reverse_cumsum, R.reverse_cumsum_ref),
])
def test_scans(fn, ref):
    x = randn(32, 64) * 0.1
    np.testing.assert_allclose(fn(x, block_rows=16), ref(x), rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(cols=st.integers(2, 257))
def test_cumsum_property(cols):
    rng = np.random.default_rng(cols)
    x = jnp.asarray(rng.standard_normal((16, cols)).astype(np.float32))
    np.testing.assert_allclose(K.cumsum(x, block_rows=8), R.cumsum_ref(x),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("bq", [16, 32])
def test_attention(causal, bq):
    q, k, v = randn(2, 2, 64, 32), randn(2, 2, 64, 32), randn(2, 2, 64, 32)
    out = K.attention(q, k, v, causal=causal, block_q=bq)
    np.testing.assert_allclose(out, R.attention_ref(q, k, v, causal=causal),
                               rtol=1e-4, atol=1e-4)


def test_attention_causality():
    """Changing future keys must not change past outputs under causal mask."""
    q, k, v = randn(1, 1, 64, 16), randn(1, 1, 64, 16), randn(1, 1, 64, 16)
    out1 = K.attention(q, k, v, causal=True, block_q=16)
    k2 = k.at[..., 32:, :].set(999.0)
    v2 = v.at[..., 32:, :].set(-999.0)
    out2 = K.attention(q, k2, v2, causal=True, block_q=16)
    np.testing.assert_allclose(out1[..., :32, :], out2[..., :32, :],
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([32, 64, 96]), d=st.sampled_from([8, 16, 32]),
       causal=st.booleans())
def test_attention_property(s, d, causal):
    rng = np.random.default_rng(s * d)
    q, k, v = (jnp.asarray(rng.standard_normal((1, 2, s, d)).astype(np.float32))
               for _ in range(3))
    out = K.attention(q, k, v, causal=causal, block_q=16)
    np.testing.assert_allclose(out, R.attention_ref(q, k, v, causal=causal),
                               rtol=1e-4, atol=1e-4)
