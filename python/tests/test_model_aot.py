# pytest: L2 problem graphs — every AOT variant must agree with its
# reference on random inputs, and the lowered HLO must be text-parseable
# (sanity for the interchange format the Rust runtime consumes).
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import PROBLEMS, InputSpec
from compile.aot import to_hlo_text

jax.config.update("jax_platform_name", "cpu")


def _make_inputs(prob, seed=7):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(s.shape).astype(s.dtype))
            for s in prob.inputs]


@pytest.mark.parametrize("pname", sorted(PROBLEMS))
def test_problem_variants_match_reference(pname):
    prob = PROBLEMS[pname]
    args = _make_inputs(prob)
    ref = prob.reference(*args)
    assert isinstance(ref, tuple) and len(ref) == 1
    for vname, vfn in prob.variants.items():
        out = vfn(*args)
        np.testing.assert_allclose(
            np.asarray(out[0], np.float32), np.asarray(ref[0], np.float32),
            rtol=prob.rtol, atol=prob.atol,
            err_msg=f"{pname}/{vname} diverged from reference")


@pytest.mark.parametrize("pname", sorted(PROBLEMS))
def test_problem_lowers_to_hlo_text(pname):
    prob = PROBLEMS[pname]
    specs = [s.sds() for s in prob.inputs]
    text = to_hlo_text(prob.reference, specs)
    assert text.startswith("HloModule"), text[:80]
    # one candidate variant, too
    vname = sorted(prob.variants)[0]
    text = to_hlo_text(prob.variants[vname], specs)
    assert text.startswith("HloModule")


def test_registry_covers_kernel_families():
    kb_ids = {p.kb_id for p in PROBLEMS.values()}
    # at least one problem per level of the paper's subset
    assert any(k.startswith("L1") for k in kb_ids)
    assert any(k.startswith("L2") for k in kb_ids)
    assert any(k.startswith("L3") for k in kb_ids)
    # every problem has >= 2 candidate variants (something to search over)
    for p in PROBLEMS.values():
        assert len(p.variants) >= 2, p.name


def test_input_spec_sds():
    s = InputSpec((4, 8), "float32")
    sds = s.sds()
    assert sds.shape == (4, 8) and sds.dtype == jnp.float32
